//! `RunConfig` — one parsed home for the run knobs that used to be
//! scattered across env vars (`SDRNN_BACKEND`, `SDRNN_THREADS`,
//! `SDRNN_SYSTOLIC_A`) and per-subcommand ckpt flags (`--ckpt-dir`,
//! `--every`, `--resume`, `--faults`, `--timeout-ms`).
//!
//! Every field is an `Option`: `None` means "not specified here", so
//! configs layer with [`RunConfig::overlay`] and the precedence rule is a
//! single line: **flag > job field > env** —
//! `RunConfig::from_env().overlay(&job.run).overlay(&flags)`.
//!
//! The JSON round-trip ([`RunConfig::to_json`]/[`RunConfig::from_json`])
//! lets service job submissions carry the same knobs as the CLI and the
//! environment, through `util::json` like every other artifact.
//!
//! One deliberate exception: `SDRNN_FAULTS` is *not* read here. A fault
//! schedule's `@n` counters are scoped to the `Faults` instance that
//! parsed it, and the env grammar must keep its historical process-wide
//! scoping (one `kill@30` kills the 30th window *across all jobs*, which
//! is what the CI crash-recovery smokes rely on) — `RunPolicy::faults()`
//! already falls back to `util::faults::global()` for that. A `faults`
//! field set explicitly (CLI `--faults`, job JSON) gets its own
//! policy-scoped instance with its own counters.

use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use crate::gemm::backend::{
    BackendSpec, Engine, GemmBackend, Systolic, SYSTOLIC_BYTES_PER_CYCLE,
};
use crate::systolic::SystolicArray;
use crate::train::checkpoint::RunPolicy;
use crate::util::error::Result;
use crate::util::faults::Faults;
use crate::util::json::Json;

/// One layerable set of run knobs; `None` = unspecified at this layer.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunConfig {
    /// Engine name (`SDRNN_BACKEND` grammar).
    pub backend: Option<String>,
    /// Worker count (`SDRNN_THREADS` semantics: 0 auto, 1 serial member).
    pub threads: Option<usize>,
    /// Systolic array edge (`SDRNN_SYSTOLIC_A`).
    pub systolic_a: Option<usize>,
    /// Policy-scoped fault schedule (`SDRNN_FAULTS` grammar; see module
    /// doc for why the env var itself stays process-global).
    pub faults: Option<String>,
    /// Snapshot directory; enables checkpointing.
    pub ckpt_dir: Option<String>,
    /// Snapshot every N windows (default 25 when checkpointing).
    pub every: Option<usize>,
    /// Resume from the newest loadable snapshot instead of starting fresh.
    pub resume: Option<bool>,
    /// Per-window watchdog limit in milliseconds.
    pub timeout_ms: Option<u64>,
}

fn env_usize(key: &str) -> Option<usize> {
    std::env::var(key).ok().and_then(|v| v.trim().parse().ok())
}

impl RunConfig {
    /// The env layer: backend-selection knobs only (ckpt behaviour has no
    /// env spelling, and `SDRNN_FAULTS` stays process-global — module doc).
    pub fn from_env() -> RunConfig {
        RunConfig {
            backend: std::env::var("SDRNN_BACKEND").ok().filter(|s| !s.trim().is_empty()),
            threads: env_usize("SDRNN_THREADS"),
            systolic_a: env_usize("SDRNN_SYSTOLIC_A"),
            ..RunConfig::default()
        }
    }

    /// The CLI layer, from parsed `--key value` pairs. Unknown keys are
    /// ignored (subcommands carry their own non-run flags).
    pub fn from_flags(flags: &HashMap<String, String>) -> Result<RunConfig> {
        fn num<T: std::str::FromStr>(
            flags: &HashMap<String, String>, k: &str,
        ) -> Result<Option<T>> {
            match flags.get(k) {
                None => Ok(None),
                Some(v) => {
                    v.parse().map(Some).map_err(|_| crate::err!("bad value for --{k}: '{v}'"))
                }
            }
        }
        Ok(RunConfig {
            backend: flags.get("backend").cloned(),
            threads: num(flags, "threads")?,
            systolic_a: num(flags, "systolic-a")?,
            faults: flags.get("faults").cloned(),
            ckpt_dir: flags.get("ckpt-dir").cloned(),
            every: num(flags, "every")?,
            resume: num::<usize>(flags, "resume")?.map(|n| n != 0),
            timeout_ms: num(flags, "timeout-ms")?,
        })
    }

    /// Layer `over` on top of `self`: every field `over` specifies wins.
    pub fn overlay(&self, over: &RunConfig) -> RunConfig {
        RunConfig {
            backend: over.backend.clone().or_else(|| self.backend.clone()),
            threads: over.threads.or(self.threads),
            systolic_a: over.systolic_a.or(self.systolic_a),
            faults: over.faults.clone().or_else(|| self.faults.clone()),
            ckpt_dir: over.ckpt_dir.clone().or_else(|| self.ckpt_dir.clone()),
            every: over.every.or(self.every),
            resume: over.resume.or(self.resume),
            timeout_ms: over.timeout_ms.or(self.timeout_ms),
        }
    }

    /// JSON object with only the specified fields (round-trips through
    /// [`RunConfig::from_json`]).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        if let Some(v) = &self.backend {
            m.insert("backend".into(), Json::Str(v.clone()));
        }
        if let Some(v) = self.threads {
            m.insert("threads".into(), Json::Num(v as f64));
        }
        if let Some(v) = self.systolic_a {
            m.insert("systolic_a".into(), Json::Num(v as f64));
        }
        if let Some(v) = &self.faults {
            m.insert("faults".into(), Json::Str(v.clone()));
        }
        if let Some(v) = &self.ckpt_dir {
            m.insert("ckpt_dir".into(), Json::Str(v.clone()));
        }
        if let Some(v) = self.every {
            m.insert("every".into(), Json::Num(v as f64));
        }
        if let Some(v) = self.resume {
            m.insert("resume".into(), Json::Bool(v));
        }
        if let Some(v) = self.timeout_ms {
            m.insert("timeout_ms".into(), Json::Num(v as f64));
        }
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<RunConfig> {
        let obj = j.as_obj().ok_or_else(|| crate::err!("RunConfig: expected object"))?;
        for key in obj.keys() {
            crate::ensure!(
                matches!(key.as_str(),
                         "backend" | "threads" | "systolic_a" | "faults" | "ckpt_dir"
                         | "every" | "resume" | "timeout_ms"),
                "RunConfig: unknown field '{key}'"
            );
        }
        let s = |k: &str| j.get(k).and_then(Json::as_str).map(str::to_string);
        let n = |k: &str| j.get(k).and_then(Json::as_usize);
        Ok(RunConfig {
            backend: s("backend"),
            threads: n("threads"),
            systolic_a: n("systolic_a"),
            faults: s("faults"),
            ckpt_dir: s("ckpt_dir"),
            every: n("every"),
            resume: j.get("resume").and_then(|v| match v {
                Json::Bool(b) => Some(*b),
                _ => None,
            }),
            timeout_ms: n("timeout_ms").map(|v| v as u64),
        })
    }

    /// The backend selection this layer stack implies, or `None` when
    /// neither `backend` nor `threads` is specified (caller keeps its
    /// ambient engine).
    pub fn backend_spec(&self) -> Result<Option<BackendSpec>> {
        if self.backend.is_none() && self.threads.is_none() {
            return Ok(None);
        }
        let threads = self.threads.map(|t| t.to_string());
        BackendSpec::parse(self.backend.as_deref(), threads.as_deref())
            .map(Some)
            .map_err(crate::util::error::Error::msg)
    }

    /// Materialize the selected engine (honouring `systolic_a` for the
    /// systolic device model), or `None` when unspecified.
    pub fn build_backend(&self) -> Result<Option<Arc<dyn GemmBackend>>> {
        let Some(spec) = self.backend_spec()? else { return Ok(None) };
        if spec.engine == Engine::Systolic {
            if let Some(a) = self.systolic_a {
                crate::ensure!(a > 0, "systolic_a must be positive");
                let array = SystolicArray::with_bandwidth(a, SYSTOLIC_BYTES_PER_CYCLE);
                return Ok(Some(Arc::new(Systolic::new(array))));
            }
        }
        Ok(Some(spec.build()))
    }

    /// The checkpoint/fault policy this config implies, plus the resume
    /// flag. Mirrors the historical CLI behaviour: `--ckpt-dir` enables
    /// checkpointing at `--every` (default 25); an explicit `faults` field
    /// becomes a policy-scoped schedule; absent one, `RunPolicy::faults()`
    /// falls back to the process-global env schedule. The caller decides
    /// what a fresh (non-resume) run does with stale snapshots.
    pub fn policy(&self) -> Result<(RunPolicy, bool)> {
        let mut policy = match &self.ckpt_dir {
            Some(d) => RunPolicy::every(Path::new(d), self.every.unwrap_or(25)),
            None => RunPolicy::none(),
        };
        if let Some(spec) = &self.faults {
            policy.faults = Some(Arc::new(Faults::parse(spec)?));
        }
        if let Some(ms) = self.timeout_ms {
            if ms > 0 {
                policy.window_timeout = Some(Duration::from_millis(ms));
            }
        }
        Ok((policy, self.resume.unwrap_or(false)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full() -> RunConfig {
        RunConfig {
            backend: Some("simd".into()),
            threads: Some(4),
            systolic_a: Some(64),
            faults: Some("lm.window:io@3".into()),
            ckpt_dir: Some("/tmp/x".into()),
            every: Some(7),
            resume: Some(true),
            timeout_ms: Some(1500),
        }
    }

    #[test]
    fn json_round_trips_full_and_empty() {
        let cfg = full();
        assert_eq!(RunConfig::from_json(&cfg.to_json()).unwrap(), cfg);
        let empty = RunConfig::default();
        assert_eq!(empty.to_json().to_string(), "{}");
        assert_eq!(RunConfig::from_json(&empty.to_json()).unwrap(), empty);
    }

    #[test]
    fn from_json_rejects_unknown_fields() {
        let j = Json::parse(r#"{"backend":"simd","bogus":1}"#).unwrap();
        let err = RunConfig::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("unknown field 'bogus'"), "{err}");
    }

    #[test]
    fn overlay_prefers_the_upper_layer_per_field() {
        let env = RunConfig { backend: Some("reference".into()), every: Some(25),
                              ..RunConfig::default() };
        let job = RunConfig { backend: Some("simd".into()), threads: Some(2),
                              ..RunConfig::default() };
        let flags = RunConfig { threads: Some(1), ..RunConfig::default() };
        let merged = env.overlay(&job).overlay(&flags);
        assert_eq!(merged.backend.as_deref(), Some("simd"), "job beats env");
        assert_eq!(merged.threads, Some(1), "flag beats job");
        assert_eq!(merged.every, Some(25), "env survives when unset above");
    }

    #[test]
    fn backend_spec_resolves_engine_and_threads() {
        assert_eq!(RunConfig::default().backend_spec().unwrap(), None);
        let cfg = RunConfig { backend: Some("parallel-simd".into()), threads: Some(3),
                              ..RunConfig::default() };
        let spec = cfg.backend_spec().unwrap().unwrap();
        assert_eq!(spec.engine, Engine::ParallelSimd);
        assert_eq!(spec.threads, 3);
        let bad = RunConfig { backend: Some("quantum".into()), ..RunConfig::default() };
        assert!(bad.backend_spec().is_err());
    }

    #[test]
    fn systolic_a_shapes_the_built_engine() {
        let cfg = RunConfig { backend: Some("systolic".into()), systolic_a: Some(32),
                              ..RunConfig::default() };
        let be = cfg.build_backend().unwrap().unwrap();
        assert_eq!(be.name(), "systolic");
    }

    #[test]
    fn policy_mirrors_the_legacy_ckpt_flags() {
        let (policy, resume) = RunConfig::default().policy().unwrap();
        assert!(policy.ckpt_dir.is_none());
        assert!(!resume);
        let (policy, resume) = full().policy().unwrap();
        assert_eq!(policy.ckpt_dir.as_deref(), Some(Path::new("/tmp/x")));
        assert_eq!(policy.every_windows, 7);
        assert!(policy.faults.is_some(), "explicit faults are policy-scoped");
        assert_eq!(policy.window_timeout, Some(Duration::from_millis(1500)));
        assert!(resume);
    }

    #[test]
    fn flags_layer_parses_the_shared_spellings() {
        let mut flags = HashMap::new();
        flags.insert("ckpt-dir".to_string(), "/tmp/c".to_string());
        flags.insert("every".to_string(), "5".to_string());
        flags.insert("resume".to_string(), "1".to_string());
        flags.insert("hidden".to_string(), "64".to_string()); // ignored
        let cfg = RunConfig::from_flags(&flags).unwrap();
        assert_eq!(cfg.ckpt_dir.as_deref(), Some("/tmp/c"));
        assert_eq!(cfg.every, Some(5));
        assert_eq!(cfg.resume, Some(true));
        assert_eq!(cfg.backend, None);
        flags.insert("threads".to_string(), "nope".to_string());
        assert!(RunConfig::from_flags(&flags).is_err());
    }
}
