//! The paper's §4.2 NMT model: a 2-layer unidirectional LSTM
//! encoder-decoder with Luong global attention (Luong et al., 2015),
//! trained with structured dropout on the non-recurrent (and optionally
//! recurrent) connections, exactly as the paper modifies OpenNMT-py.
//!
//! Exact BPTT through decoder (incl. attention, which backprops into the
//! encoder outputs) and then through the encoder. Both sequence loops run
//! on the unified [`crate::rnn`] runtime: the encoder and decoder each own
//! a [`Workspace`] (tape + scratch) inside [`NmtWorkspace`], and the
//! decoder's initial-state gradients feed the encoder's backward pass as
//! its carry-in gradient — the `dh_next`/`dc_next` plumbing lives in one
//! place, not four.

use crate::data::batcher::{gather_step_ids, PairBatch};
use crate::dropout::plan::MaskPlanner;
use crate::dropout::rng::XorShift64;
use crate::gemm::sparse::SparseScratch;
use crate::model::attention::{Attention, AttentionGrads, AttnCache};
use crate::model::embedding::Embedding;
use crate::model::linear::{Linear, LinearGrads};
use crate::model::lstm::{LstmGrads, LstmParams};
use crate::model::softmax::{ce_bwd_into, ce_fwd_into};
use crate::rnn::tape::size_buf;
use crate::rnn::{Direction, StackedLstm, StepBufs, UnitMasks, Workspace};
use crate::train::timing::PhaseTimer;

/// NMT configuration (paper: H=512, 2 layers, p=0.3 NR).
#[derive(Debug, Clone, Copy)]
pub struct NmtConfig {
    pub src_vocab: usize,
    pub tgt_vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub init_scale: f32,
}

/// Encoder-decoder parameters.
#[derive(Debug, Clone)]
pub struct NmtModel {
    pub cfg: NmtConfig,
    pub src_emb: Embedding,
    pub enc: Vec<LstmParams>,
    pub tgt_emb: Embedding,
    pub dec: Vec<LstmParams>,
    pub attn: Attention,
    pub proj: Linear,
}

/// Gradients matching [`NmtModel`].
#[derive(Debug, Clone)]
pub struct NmtGrads {
    pub dsrc_emb: Vec<f32>,
    pub enc: Vec<LstmGrads>,
    pub dtgt_emb: Vec<f32>,
    pub dec: Vec<LstmGrads>,
    pub attn: AttentionGrads,
    pub proj: LinearGrads,
}

impl NmtGrads {
    pub fn zeros(m: &NmtModel) -> NmtGrads {
        NmtGrads {
            dsrc_emb: vec![0.0; m.src_emb.w.len()],
            enc: m.enc.iter().map(LstmGrads::zeros).collect(),
            dtgt_emb: vec![0.0; m.tgt_emb.w.len()],
            dec: m.dec.iter().map(LstmGrads::zeros).collect(),
            attn: AttentionGrads::zeros(&m.attn),
            proj: LinearGrads::zeros(&m.proj),
        }
    }

    pub fn zero(&mut self) {
        self.dsrc_emb.fill(0.0);
        self.dtgt_emb.fill(0.0);
        for g in self.enc.iter_mut().chain(self.dec.iter_mut()) {
            g.zero();
        }
        self.attn.zero();
        self.proj.zero();
    }

    pub fn buffers_mut(&mut self) -> Vec<&mut [f32]> {
        let mut v: Vec<&mut [f32]> = vec![&mut self.dsrc_emb];
        for g in &mut self.enc {
            v.push(&mut g.dw);
            v.push(&mut g.du);
            v.push(&mut g.db);
        }
        v.push(&mut self.dtgt_emb);
        for g in &mut self.dec {
            v.push(&mut g.dw);
            v.push(&mut g.du);
            v.push(&mut g.db);
        }
        v.push(&mut self.attn.dwc);
        v.push(&mut self.attn.dbc);
        v.push(&mut self.proj.dw);
        v.push(&mut self.proj.db);
        v
    }
}

/// Preallocated working memory for NMT training: one sequence-runtime
/// workspace per stack (encoder, decoder) plus the head-side buffers
/// (embeddings, encoder outputs `he` and their gradient, attention
/// residuals, softmax caches). Create once per run and reuse across
/// batches; buffers grow to the longest batch and stay.
#[derive(Debug, Default)]
pub struct NmtWorkspace {
    enc: Workspace,
    dec: Workspace,
    enc_xs: StepBufs,
    dec_xs: StepBufs,
    enc_dtop: StepBufs,
    dec_dtop: StepBufs,
    probs: StepBufs,
    head_xd: StepBufs,
    /// Top-layer encoder outputs after output dropout, `[b, s_max, h]`.
    he: Vec<f32>,
    /// Gradient on `he`, accumulated by attention backward.
    dhe: Vec<f32>,
    /// Attention output ĥ of the current step, `[b, h]`.
    hhat: Vec<f32>,
    /// Gradient on ĥ of the current step, `[b, h]`.
    dhhat: Vec<f32>,
    /// Masked top-layer encoder output of the current step, `[b, h]`.
    top_masked: Vec<f32>,
    /// Encoder final states (decoder carry-in), per layer `[b, h]`.
    enc_final_h: Vec<Vec<f32>>,
    enc_final_c: Vec<Vec<f32>>,
    logits: Vec<f32>,
    dlogits: Vec<f32>,
    ids: Vec<i32>,
    targets: Vec<Vec<i32>>,
    attn_caches: Vec<AttnCache>,
    scratch: SparseScratch,
}

impl NmtWorkspace {
    pub fn new() -> NmtWorkspace {
        NmtWorkspace::default()
    }
}

impl NmtModel {
    pub fn init(cfg: NmtConfig, rng: &mut XorShift64) -> NmtModel {
        let s = cfg.init_scale;
        NmtModel {
            cfg,
            src_emb: Embedding::init(cfg.src_vocab, cfg.hidden, s, rng),
            enc: (0..cfg.layers)
                .map(|_| LstmParams::init(cfg.hidden, cfg.hidden, s, rng))
                .collect(),
            tgt_emb: Embedding::init(cfg.tgt_vocab, cfg.hidden, s, rng),
            dec: (0..cfg.layers)
                .map(|_| LstmParams::init(cfg.hidden, cfg.hidden, s, rng))
                .collect(),
            attn: Attention::init(cfg.hidden, s, rng),
            proj: Linear::init(cfg.hidden, cfg.tgt_vocab, s, rng),
        }
    }

    pub fn buffers_mut(&mut self) -> Vec<&mut [f32]> {
        let mut v: Vec<&mut [f32]> = vec![&mut self.src_emb.w];
        for p in &mut self.enc {
            v.push(&mut p.w);
            v.push(&mut p.u);
            v.push(&mut p.b);
        }
        v.push(&mut self.tgt_emb.w);
        for p in &mut self.dec {
            v.push(&mut p.w);
            v.push(&mut p.u);
            v.push(&mut p.b);
        }
        v.push(&mut self.attn.wc);
        v.push(&mut self.attn.bc);
        v.push(&mut self.proj.w);
        v.push(&mut self.proj.b);
        v
    }

    /// Immutable view in the same order as [`Self::buffers_mut`] (for
    /// checkpointing / fingerprinting).
    pub fn buffers(&self) -> Vec<&[f32]> {
        let mut v: Vec<&[f32]> = vec![&self.src_emb.w];
        for p in &self.enc {
            v.push(&p.w);
            v.push(&p.u);
            v.push(&p.b);
        }
        v.push(&self.tgt_emb.w);
        for p in &self.dec {
            v.push(&p.w);
            v.push(&p.u);
            v.push(&p.b);
        }
        v.push(&self.attn.wc);
        v.push(&self.attn.bc);
        v.push(&self.proj.w);
        v.push(&self.proj.b);
        v
    }

    /// One training batch: full fwd+bwd. Returns mean per-token NLL over
    /// non-pad target positions. Masks are planned per time step from
    /// `planner` (fresh patterns each step — "randomized in time").
    pub fn train_batch(
        &self,
        batch: &PairBatch,
        planner: &mut MaskPlanner,
        grads: &mut NmtGrads,
        ws: &mut NmtWorkspace,
        timer: &mut PhaseTimer,
    ) -> f64 {
        timer.window(|t| self.train_batch_inner(batch, planner, grads, ws, t))
    }

    fn train_batch_inner(
        &self,
        batch: &PairBatch,
        planner: &mut MaskPlanner,
        grads: &mut NmtGrads,
        ws: &mut NmtWorkspace,
        timer: &mut PhaseTimer,
    ) -> f64 {
        grads.zero();
        let cfg = &self.cfg;
        let (h, l) = (cfg.hidden, cfg.layers);
        let b = batch.b;
        let (s_max, t_max) = (batch.src_max, batch.tgt_max);

        // ---------------- encoder forward ----------------
        let enc_plan = planner.plan(s_max, b, h, l);
        ws.enc_xs.ensure(s_max, b * h);
        for t in 0..s_max {
            gather_step_ids(&mut ws.ids, &batch.src, b, s_max, t);
            self.src_emb.fwd(&ws.ids, ws.enc_xs.buf_mut(t));
        }
        let enc_rt = StackedLstm::new(&self.enc);
        enc_rt.forward(&mut ws.enc, &ws.enc_xs, &enc_plan, s_max, b, None,
                       Direction::Forward, timer);

        // Top-layer outputs through the encoder-output dropout mask into
        // the attention memory `he` (paper: extra 0.3 on encoder output).
        size_buf(&mut ws.he, b * s_max * h);
        size_buf(&mut ws.top_masked, b * h);
        for t in 0..s_max {
            ws.top_masked.copy_from_slice(ws.enc.tape.h_top(t));
            enc_plan.steps[t].mx[l].apply(&mut ws.top_masked, b);
            for r in 0..b {
                ws.he[(r * s_max + t) * h..(r * s_max + t + 1) * h]
                    .copy_from_slice(&ws.top_masked[r * h..(r + 1) * h]);
            }
        }
        // Encoder final state initializes the decoder.
        size_state(&mut ws.enc_final_h, l, b * h);
        size_state(&mut ws.enc_final_c, l, b * h);
        for li in 0..l {
            ws.enc_final_h[li].copy_from_slice(ws.enc.tape.h_out(s_max - 1, li));
            ws.enc_final_c[li].copy_from_slice(ws.enc.tape.c_out(s_max - 1, li));
        }

        // ---------------- decoder forward ----------------
        let dec_plan = planner.plan(t_max, b, h, l);
        ws.dec_xs.ensure(t_max, b * h);
        for t in 0..t_max {
            gather_step_ids(&mut ws.ids, &batch.tgt_in, b, t_max, t);
            self.tgt_emb.fwd(&ws.ids, ws.dec_xs.buf_mut(t));
        }
        let dec_rt = StackedLstm::new(&self.dec);
        dec_rt.forward(&mut ws.dec, &ws.dec_xs, &dec_plan, t_max, b,
                       Some((ws.enc_final_h.as_slice(), ws.enc_final_c.as_slice())),
                       Direction::Forward, timer);

        // Attention + output dropout + projection + CE per step.
        ws.probs.ensure(t_max, b * cfg.tgt_vocab);
        ws.head_xd.ensure(t_max, b * h);
        ws.dec_dtop.ensure(t_max, b * h);
        size_buf(&mut ws.hhat, b * h);
        size_buf(&mut ws.logits, b * cfg.tgt_vocab);
        size_buf(&mut ws.dlogits, b * cfg.tgt_vocab);
        if ws.targets.len() < t_max {
            ws.targets.resize_with(t_max, Vec::new);
        }
        ws.attn_caches.clear();
        let mut loss_sum = 0.0f64;
        let mut n_tokens = 0usize;
        for t in 0..t_max {
            let ac = self.attn.fwd(ws.dec.tape.h_top(t), &ws.he, &batch.src_len,
                                   b, s_max, timer, &mut ws.hhat);
            ws.attn_caches.push(ac);

            self.proj.fwd_ws(&ws.hhat, &dec_plan.steps[t].mx[l], b, timer,
                             ws.head_xd.vec_mut(t), &mut ws.logits, &mut ws.scratch);

            // CE with pad masking: positions past tgt_len get target -1.
            let targets = &mut ws.targets[t];
            targets.clear();
            targets.extend((0..b).map(|r| {
                if t < batch.tgt_len[r] { batch.tgt_out[r * t_max + t] } else { -1 }
            }));
            n_tokens += targets.iter().filter(|&&x| x >= 0).count();
            loss_sum += ce_fwd_into(&ws.logits, targets, b, cfg.tgt_vocab,
                                    ws.probs.buf_mut(t));
        }

        // ---------------- decoder backward ----------------
        let inv = 1.0 / n_tokens.max(1) as f32;
        size_buf(&mut ws.dhe, b * s_max * h);
        ws.dhe.fill(0.0);
        size_buf(&mut ws.dhhat, b * h);
        for t in (0..t_max).rev() {
            ce_bwd_into(ws.probs.buf(t), &ws.targets[t], b, cfg.tgt_vocab, inv,
                        &mut ws.dlogits);
            self.proj.bwd_ws(ws.head_xd.buf(t), &dec_plan.steps[t].mx[l], &ws.dlogits,
                             b, &mut grads.proj, timer, &mut ws.dhhat, &mut ws.scratch);
            let datt = self.attn.bwd(&ws.attn_caches[t], &ws.he, &batch.src_len,
                                     &ws.dhhat, b, &mut grads.attn, &mut ws.dhe, timer);
            ws.dec_dtop.buf_mut(t).copy_from_slice(&datt);
        }
        let mut sink_ids: Vec<i32> = vec![0; b];
        dec_rt.backward(&mut ws.dec, &ws.dec_dtop, &dec_plan, t_max, b, None,
                        &mut grads.dec, Direction::Forward, timer, |t, dx| {
                            for (r, id) in sink_ids.iter_mut().enumerate() {
                                *id = batch.tgt_in[r * t_max + t];
                            }
                            self.tgt_emb.bwd(&sink_ids, dx, &mut grads.dtgt_emb);
                        });

        // ---------------- encoder backward ----------------
        // Per-step gradient on the top-layer output: attention's dHe pulled
        // back through the encoder-output dropout mask.
        ws.enc_dtop.ensure(s_max, b * h);
        for t in 0..s_max {
            let d = ws.enc_dtop.buf_mut(t);
            for r in 0..b {
                d[r * h..(r + 1) * h]
                    .copy_from_slice(&ws.dhe[(r * s_max + t) * h..(r * s_max + t + 1) * h]);
            }
            enc_plan.steps[t].mx[l].apply(d, b);
        }
        // Decoder initial-state gradients flow into the encoder final state.
        let (dec_dh0, dec_dc0) = ws.dec.state_grads();
        enc_rt.backward(&mut ws.enc, &ws.enc_dtop, &enc_plan, s_max, b,
                        Some((dec_dh0, dec_dc0)), &mut grads.enc,
                        Direction::Forward, timer, |t, dx| {
                            for (r, id) in sink_ids.iter_mut().enumerate() {
                                *id = batch.src[r * s_max + t];
                            }
                            self.src_emb.bwd(&sink_ids, dx, &mut grads.dsrc_emb);
                        });

        loss_sum / n_tokens.max(1) as f64
    }

    /// Greedy decode (eval): argmax feed-back, dropout disabled. Returns
    /// one hypothesis per batch row (stops at `eos` or `max_steps`).
    pub fn greedy_decode(
        &self, batch: &PairBatch, eos: u32, max_steps: usize,
    ) -> Vec<Vec<u32>> {
        let cfg = &self.cfg;
        let (h, l) = (cfg.hidden, cfg.layers);
        let b = batch.b;
        let s_max = batch.src_max;
        let mut ws = NmtWorkspace::new();
        let mut timer = PhaseTimer::new();
        let enc_unit = UnitMasks::for_layers(&self.enc);
        let dec_unit = UnitMasks::for_layers(&self.dec);

        // Encoder over the full source window, identity masks.
        ws.enc_xs.ensure(s_max, b * h);
        for t in 0..s_max {
            gather_step_ids(&mut ws.ids, &batch.src, b, s_max, t);
            self.src_emb.fwd(&ws.ids, ws.enc_xs.buf_mut(t));
        }
        let enc_rt = StackedLstm::new(&self.enc);
        enc_rt.forward(&mut ws.enc, &ws.enc_xs, &enc_unit, s_max, b, None,
                       Direction::Forward, &mut timer);
        size_buf(&mut ws.he, b * s_max * h);
        for t in 0..s_max {
            let top = ws.enc.tape.h_top(t);
            for r in 0..b {
                ws.he[(r * s_max + t) * h..(r * s_max + t + 1) * h]
                    .copy_from_slice(&top[r * h..(r + 1) * h]);
            }
        }

        // Decoder, greedy: one-step windows with explicit state carry.
        let mut dhs: Vec<Vec<f32>> =
            (0..l).map(|li| ws.enc.tape.h_out(s_max - 1, li).to_vec()).collect();
        let mut dcs: Vec<Vec<f32>> =
            (0..l).map(|li| ws.enc.tape.c_out(s_max - 1, li).to_vec()).collect();
        let dec_rt = StackedLstm::new(&self.dec);
        let ones = crate::dropout::mask::Mask::Ones { h };
        ws.dec_xs.ensure(1, b * h);
        size_buf(&mut ws.hhat, b * h);
        size_buf(&mut ws.logits, b * cfg.tgt_vocab);
        ws.head_xd.ensure(1, b * h);

        let mut cur: Vec<i32> = vec![crate::data::vocab::BOS as i32; b];
        let mut hyps: Vec<Vec<u32>> = vec![Vec::new(); b];
        let mut done = vec![false; b];
        for _ in 0..max_steps {
            self.tgt_emb.fwd(&cur, ws.dec_xs.buf_mut(0));
            dec_rt.forward(&mut ws.dec, &ws.dec_xs, &dec_unit, 1, b,
                           Some((dhs.as_slice(), dcs.as_slice())), Direction::Forward, &mut timer);
            for li in 0..l {
                dhs[li].copy_from_slice(ws.dec.tape.h_out(0, li));
                dcs[li].copy_from_slice(ws.dec.tape.c_out(0, li));
            }
            self.attn.fwd(ws.dec.tape.h_top(0), &ws.he, &batch.src_len, b, s_max,
                          &mut timer, &mut ws.hhat);
            self.proj.fwd_ws(&ws.hhat, &ones, b, &mut timer, ws.head_xd.vec_mut(0),
                             &mut ws.logits, &mut ws.scratch);
            for r in 0..b {
                if done[r] {
                    cur[r] = eos as i32;
                    continue;
                }
                let row = &ws.logits[r * cfg.tgt_vocab..(r + 1) * cfg.tgt_vocab];
                let best = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as u32)
                    .unwrap();
                if best == eos {
                    done[r] = true;
                } else {
                    hyps[r].push(best);
                }
                cur[r] = best as i32;
            }
            if done.iter().all(|&d| d) {
                break;
            }
        }
        hyps
    }
}

/// Size a per-layer state buffer pool.
fn size_state(state: &mut Vec<Vec<f32>>, layers: usize, n: usize) {
    if state.len() < layers {
        state.resize_with(layers, Vec::new);
    }
    for s in &mut state[..layers] {
        size_buf(s, n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::batcher::PairBatcher;
    use crate::data::corpus::ParallelCorpus;
    use crate::dropout::plan::DropoutConfig;

    fn tiny_model() -> (NmtModel, XorShift64) {
        let mut rng = XorShift64::new(1);
        let cfg = NmtConfig {
            src_vocab: 40,
            tgt_vocab: 45,
            hidden: 10,
            layers: 2,
            init_scale: 0.15,
        };
        (NmtModel::init(cfg, &mut rng), rng)
    }

    fn tiny_batch() -> PairBatch {
        let pc = ParallelCorpus::new(36, 3);
        let pairs = pc.pairs(4, 3, 6, 5);
        PairBatcher::new(&pairs, 4, crate::data::vocab::BOS, crate::data::vocab::EOS)
            .batches()[0]
            .clone()
    }

    #[test]
    fn initial_loss_near_ln_v() {
        let (m, _) = tiny_model();
        let batch = tiny_batch();
        let mut planner = MaskPlanner::new(DropoutConfig::none(), 7);
        let mut grads = NmtGrads::zeros(&m);
        let mut ws = NmtWorkspace::new();
        let mut timer = PhaseTimer::new();
        let wall0 = std::time::Instant::now();
        let loss = m.train_batch(&batch, &mut planner, &mut grads, &mut ws, &mut timer);
        let wall = wall0.elapsed();
        assert!((loss - (45f64).ln()).abs() < 0.6, "loss={loss}");
        assert!(timer.fp > std::time::Duration::ZERO);
        assert!(timer.wg > std::time::Duration::ZERO);
        // Centralized attribution: phase sum is bounded by the measured
        // wall clock, with the attention/softmax remainder in Other.
        assert!(timer.total() <= wall,
                "phases {:?} exceed batch wall time {wall:?}", timer.total());
        assert!(timer.other > std::time::Duration::ZERO);
    }

    #[test]
    fn grads_finite_difference_spot_check() {
        let (m, _) = tiny_model();
        let batch = tiny_batch();
        // Fixed dropout plan via a reseeded planner each call.
        let loss_of = |m: &NmtModel| {
            let mut planner = MaskPlanner::new(DropoutConfig::nr_st(0.3), 42);
            let mut g = NmtGrads::zeros(m);
            let mut w = NmtWorkspace::new();
            let mut t = PhaseTimer::new();
            m.train_batch(&batch, &mut planner, &mut g, &mut w, &mut t)
        };
        let mut grads = NmtGrads::zeros(&m);
        {
            let mut planner = MaskPlanner::new(DropoutConfig::nr_st(0.3), 42);
            let mut w = NmtWorkspace::new();
            let mut t = PhaseTimer::new();
            m.train_batch(&batch, &mut planner, &mut grads, &mut w, &mut t);
        }
        let eps = 1e-2f32;
        // buffers: 0=src_emb, 1..7 enc, 7=tgt_emb, 8..14 dec, 14=wc, 16=proj_w
        for (buf_idx, coord) in [(0usize, 11usize), (2, 5), (7, 3), (9, 8), (14, 2), (16, 1)] {
            let analytic = grads.buffers_mut()[buf_idx][coord];
            let mut mp = m.clone();
            mp.buffers_mut()[buf_idx][coord] += eps;
            let mut mm = m.clone();
            mm.buffers_mut()[buf_idx][coord] -= eps;
            let num = ((loss_of(&mp) - loss_of(&mm)) / (2.0 * eps as f64)) as f32;
            assert!(
                (analytic - num).abs() < 4e-2 * (1.0 + num.abs()),
                "buffer {buf_idx}[{coord}]: analytic {analytic} vs numeric {num}"
            );
        }
    }

    #[test]
    fn sgd_learns_the_transduction() {
        // Memorization-scale check: a handful of short pairs must be
        // drivable to low loss (generalization is tested at experiment
        // scale by examples/nmt_iwslt.rs).
        let (mut m, _) = tiny_model();
        let pc = ParallelCorpus::new(36, 3);
        let pairs = pc.pairs(8, 3, 5, 9);
        let pb = PairBatcher::new(&pairs, 8, crate::data::vocab::BOS, crate::data::vocab::EOS);
        let mut planner = MaskPlanner::new(DropoutConfig::nr_st(0.1), 13);
        let mut grads = NmtGrads::zeros(&m);
        let mut ws = NmtWorkspace::new();
        let mut timer = PhaseTimer::new();
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..40 {
            for batch in pb.batches() {
                let loss = m.train_batch(batch, &mut planner, &mut grads, &mut ws, &mut timer);
                if first.is_none() {
                    first = Some(loss);
                }
                last = loss;
                for (p, g) in m.buffers_mut().into_iter().zip(grads.buffers_mut()) {
                    for (pv, gv) in p.iter_mut().zip(g.iter()) {
                        *pv -= 0.7 * gv;
                    }
                }
            }
        }
        assert!(last < first.unwrap() * 0.8,
                "NMT loss did not drop: {:?} -> {last}", first);
    }

    #[test]
    fn greedy_decode_produces_bounded_hyps() {
        let (m, _) = tiny_model();
        let batch = tiny_batch();
        let hyps = m.greedy_decode(&batch, crate::data::vocab::EOS, 12);
        assert_eq!(hyps.len(), batch.b);
        for hyp in &hyps {
            assert!(hyp.len() <= 12);
            assert!(hyp.iter().all(|&t| (t as usize) < m.cfg.tgt_vocab));
        }
    }
}
