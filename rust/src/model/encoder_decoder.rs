//! The paper's §4.2 NMT model: a 2-layer unidirectional LSTM
//! encoder-decoder with Luong global attention (Luong et al., 2015),
//! trained with structured dropout on the non-recurrent (and optionally
//! recurrent) connections, exactly as the paper modifies OpenNMT-py.
//!
//! Exact BPTT through decoder (incl. attention, which backprops into the
//! encoder outputs) and then through the encoder.

use crate::data::batcher::PairBatch;
use crate::dropout::mask::Mask;
use crate::dropout::plan::MaskPlanner;
use crate::dropout::rng::XorShift64;
use crate::model::attention::{Attention, AttentionGrads};
use crate::model::embedding::Embedding;
use crate::model::linear::{Linear, LinearGrads};
use crate::model::lstm::{cell_bwd, cell_fwd, CellCache, LstmGrads, LstmParams};
use crate::model::softmax::{ce_bwd, ce_fwd};
use crate::train::timing::{Phase, PhaseTimer};

/// NMT configuration (paper: H=512, 2 layers, p=0.3 NR).
#[derive(Debug, Clone, Copy)]
pub struct NmtConfig {
    pub src_vocab: usize,
    pub tgt_vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub init_scale: f32,
}

/// Encoder-decoder parameters.
#[derive(Debug, Clone)]
pub struct NmtModel {
    pub cfg: NmtConfig,
    pub src_emb: Embedding,
    pub enc: Vec<LstmParams>,
    pub tgt_emb: Embedding,
    pub dec: Vec<LstmParams>,
    pub attn: Attention,
    pub proj: Linear,
}

/// Gradients matching [`NmtModel`].
#[derive(Debug, Clone)]
pub struct NmtGrads {
    pub dsrc_emb: Vec<f32>,
    pub enc: Vec<LstmGrads>,
    pub dtgt_emb: Vec<f32>,
    pub dec: Vec<LstmGrads>,
    pub attn: AttentionGrads,
    pub proj: LinearGrads,
}

impl NmtGrads {
    pub fn zeros(m: &NmtModel) -> NmtGrads {
        NmtGrads {
            dsrc_emb: vec![0.0; m.src_emb.w.len()],
            enc: m.enc.iter().map(LstmGrads::zeros).collect(),
            dtgt_emb: vec![0.0; m.tgt_emb.w.len()],
            dec: m.dec.iter().map(LstmGrads::zeros).collect(),
            attn: AttentionGrads::zeros(&m.attn),
            proj: LinearGrads::zeros(&m.proj),
        }
    }

    pub fn zero(&mut self) {
        self.dsrc_emb.fill(0.0);
        self.dtgt_emb.fill(0.0);
        for g in self.enc.iter_mut().chain(self.dec.iter_mut()) {
            g.zero();
        }
        self.attn.zero();
        self.proj.zero();
    }

    pub fn buffers_mut(&mut self) -> Vec<&mut [f32]> {
        let mut v: Vec<&mut [f32]> = vec![&mut self.dsrc_emb];
        for g in &mut self.enc {
            v.push(&mut g.dw);
            v.push(&mut g.du);
            v.push(&mut g.db);
        }
        v.push(&mut self.dtgt_emb);
        for g in &mut self.dec {
            v.push(&mut g.dw);
            v.push(&mut g.du);
            v.push(&mut g.db);
        }
        v.push(&mut self.attn.dwc);
        v.push(&mut self.attn.dbc);
        v.push(&mut self.proj.dw);
        v.push(&mut self.proj.db);
        v
    }
}

impl NmtModel {
    pub fn init(cfg: NmtConfig, rng: &mut XorShift64) -> NmtModel {
        let s = cfg.init_scale;
        NmtModel {
            cfg,
            src_emb: Embedding::init(cfg.src_vocab, cfg.hidden, s, rng),
            enc: (0..cfg.layers)
                .map(|_| LstmParams::init(cfg.hidden, cfg.hidden, s, rng))
                .collect(),
            tgt_emb: Embedding::init(cfg.tgt_vocab, cfg.hidden, s, rng),
            dec: (0..cfg.layers)
                .map(|_| LstmParams::init(cfg.hidden, cfg.hidden, s, rng))
                .collect(),
            attn: Attention::init(cfg.hidden, s, rng),
            proj: Linear::init(cfg.hidden, cfg.tgt_vocab, s, rng),
        }
    }

    pub fn buffers_mut(&mut self) -> Vec<&mut [f32]> {
        let mut v: Vec<&mut [f32]> = vec![&mut self.src_emb.w];
        for p in &mut self.enc {
            v.push(&mut p.w);
            v.push(&mut p.u);
            v.push(&mut p.b);
        }
        v.push(&mut self.tgt_emb.w);
        for p in &mut self.dec {
            v.push(&mut p.w);
            v.push(&mut p.u);
            v.push(&mut p.b);
        }
        v.push(&mut self.attn.wc);
        v.push(&mut self.attn.bc);
        v.push(&mut self.proj.w);
        v.push(&mut self.proj.b);
        v
    }

    /// One training batch: full fwd+bwd. Returns mean per-token NLL over
    /// non-pad target positions. Masks are planned per time step from
    /// `planner` (fresh patterns each step — "randomized in time").
    pub fn train_batch(
        &self,
        batch: &PairBatch,
        planner: &mut MaskPlanner,
        grads: &mut NmtGrads,
        timer: &mut PhaseTimer,
    ) -> f64 {
        grads.zero();
        let cfg = &self.cfg;
        let (h, l) = (cfg.hidden, cfg.layers);
        let b = batch.b;
        let (s_max, t_max) = (batch.src_max, batch.tgt_max);

        // ---------------- encoder forward ----------------
        let enc_plan = planner.plan(s_max, b, h, l);
        let mut ehs: Vec<Vec<f32>> = (0..l).map(|_| vec![0.0; b * h]).collect();
        let mut ecs: Vec<Vec<f32>> = (0..l).map(|_| vec![0.0; b * h]).collect();
        let mut enc_caches: Vec<Vec<CellCache>> = Vec::with_capacity(s_max);
        let mut he = vec![0.0f32; b * s_max * h]; // top-layer outputs
        let mut enc_out_masks: Vec<Mask> = Vec::with_capacity(s_max);
        let mut src_embs: Vec<Vec<f32>> = Vec::with_capacity(s_max);

        for t in 0..s_max {
            let ids: Vec<i32> = (0..b).map(|r| batch.src[r * s_max + t]).collect();
            let mut inp = vec![0.0f32; b * h];
            timer.time(Phase::Other, || self.src_emb.fwd(&ids, &mut inp));
            src_embs.push(inp.clone());
            let masks = &enc_plan.steps[t];
            let mut caches = Vec::with_capacity(l);
            for li in 0..l {
                let (hn, cn, cache) = cell_fwd(
                    &self.enc[li], &inp, &ehs[li], &ecs[li],
                    &masks.mx[li], &masks.mh[li], b, timer,
                );
                ehs[li] = hn.clone();
                ecs[li] = cn;
                inp = hn;
                caches.push(cache);
            }
            enc_caches.push(caches);
            // encoder output dropout (paper: extra 0.3 on encoder output)
            let om = masks.mx[l].clone();
            let mut top = inp;
            om.apply(&mut top, b);
            enc_out_masks.push(om);
            for r in 0..b {
                he[(r * s_max + t) * h..(r * s_max + t + 1) * h]
                    .copy_from_slice(&top[r * h..(r + 1) * h]);
            }
        }

        // ---------------- decoder forward ----------------
        let dec_plan = planner.plan(t_max, b, h, l);
        let mut dhs = ehs.clone(); // init decoder state from encoder final
        let mut dcs = ecs.clone();
        let mut dec_caches: Vec<Vec<CellCache>> = Vec::with_capacity(t_max);
        let mut attn_caches = Vec::with_capacity(t_max);
        let mut lin_caches = Vec::with_capacity(t_max);
        let mut probs_per_t = Vec::with_capacity(t_max);
        let mut targets_per_t: Vec<Vec<i32>> = Vec::with_capacity(t_max);
        let mut loss_sum = 0.0f64;
        let mut n_tokens = 0usize;

        for t in 0..t_max {
            let ids: Vec<i32> = (0..b).map(|r| batch.tgt_in[r * t_max + t]).collect();
            let mut inp = vec![0.0f32; b * h];
            timer.time(Phase::Other, || self.tgt_emb.fwd(&ids, &mut inp));
            let masks = &dec_plan.steps[t];
            let mut caches = Vec::with_capacity(l);
            for li in 0..l {
                let (hn, cn, cache) = cell_fwd(
                    &self.dec[li], &inp, &dhs[li], &dcs[li],
                    &masks.mx[li], &masks.mh[li], b, timer,
                );
                dhs[li] = hn.clone();
                dcs[li] = cn;
                inp = hn;
                caches.push(cache);
            }
            dec_caches.push(caches);

            let mut hhat = vec![0.0f32; b * h];
            let ac = self.attn.fwd(&inp, &he, &batch.src_len, b, s_max, timer, &mut hhat);
            attn_caches.push(ac);

            // decoder output dropout + projection
            let mut logits = vec![0.0f32; b * cfg.tgt_vocab];
            let lc = self.proj.fwd(&hhat, &masks.mx[l], b, timer, &mut logits);
            lin_caches.push(lc);

            // CE with pad masking: positions past tgt_len get target -1.
            let targets: Vec<i32> = (0..b)
                .map(|r| if t < batch.tgt_len[r] { batch.tgt_out[r * t_max + t] } else { -1 })
                .collect();
            n_tokens += targets.iter().filter(|&&x| x >= 0).count();
            let (nll, probs) =
                timer.time(Phase::Other, || ce_fwd(&logits, &targets, b, cfg.tgt_vocab));
            loss_sum += nll;
            probs_per_t.push(probs);
            targets_per_t.push(targets);
        }

        // ---------------- decoder backward ----------------
        let inv = 1.0 / n_tokens.max(1) as f32;
        let mut dh_next: Vec<Vec<f32>> = (0..l).map(|_| vec![0.0f32; b * h]).collect();
        let mut dc_next: Vec<Vec<f32>> = (0..l).map(|_| vec![0.0f32; b * h]).collect();
        let mut dhe = vec![0.0f32; b * s_max * h];

        for t in (0..t_max).rev() {
            let dlogits = timer.time(Phase::Other, || {
                ce_bwd(&probs_per_t[t], &targets_per_t[t], b, cfg.tgt_vocab, inv)
            });
            let dhhat = self.proj.bwd(&lin_caches[t], &dlogits, b, &mut grads.proj, timer);
            let datt = self.attn.bwd(
                &attn_caches[t], &he, &batch.src_len, &dhhat, b,
                &mut grads.attn, &mut dhe, timer,
            );

            let mut dh = datt;
            for (dv, nv) in dh.iter_mut().zip(&dh_next[l - 1]) {
                *dv += nv;
            }
            let mut dx_below: Option<Vec<f32>> = None;
            for li in (0..l).rev() {
                if li < l - 1 {
                    dh = dx_below.take().unwrap();
                    for (dv, nv) in dh.iter_mut().zip(&dh_next[li]) {
                        *dv += nv;
                    }
                }
                let (dx, dhp, dcp) = cell_bwd(
                    &self.dec[li], &dec_caches[t][li], &dh, &dc_next[li], b,
                    &mut grads.dec[li], timer,
                );
                dh_next[li] = dhp;
                dc_next[li] = dcp;
                dx_below = Some(dx);
            }
            let ids: Vec<i32> = (0..b).map(|r| batch.tgt_in[r * t_max + t]).collect();
            let demb = dx_below.unwrap();
            timer.time(Phase::Other, || self.tgt_emb.bwd(&ids, &demb, &mut grads.dtgt_emb));
        }

        // ---------------- encoder backward ----------------
        // Decoder initial state gradients flow into the encoder final state.
        let mut eh_next = dh_next;
        let mut ec_next = dc_next;
        for t in (0..s_max).rev() {
            // Gradient on the top-layer output at step t: from attention
            // (through the encoder-output dropout mask).
            let mut dtop = vec![0.0f32; b * h];
            for r in 0..b {
                dtop[r * h..(r + 1) * h]
                    .copy_from_slice(&dhe[(r * s_max + t) * h..(r * s_max + t + 1) * h]);
            }
            enc_out_masks[t].apply(&mut dtop, b);
            for (dv, nv) in dtop.iter_mut().zip(&eh_next[l - 1]) {
                *dv += nv;
            }

            let mut dh = dtop;
            let mut dx_below: Option<Vec<f32>> = None;
            for li in (0..l).rev() {
                if li < l - 1 {
                    dh = dx_below.take().unwrap();
                    for (dv, nv) in dh.iter_mut().zip(&eh_next[li]) {
                        *dv += nv;
                    }
                }
                let (dx, dhp, dcp) = cell_bwd(
                    &self.enc[li], &enc_caches[t][li], &dh, &ec_next[li], b,
                    &mut grads.enc[li], timer,
                );
                eh_next[li] = dhp;
                ec_next[li] = dcp;
                dx_below = Some(dx);
            }
            let ids: Vec<i32> = (0..b).map(|r| batch.src[r * s_max + t]).collect();
            let demb = dx_below.unwrap();
            timer.time(Phase::Other, || self.src_emb.bwd(&ids, &demb, &mut grads.dsrc_emb));
            let _ = &src_embs; // residuals kept alive for clarity
        }

        loss_sum / n_tokens.max(1) as f64
    }

    /// Greedy decode (eval): argmax feed-back, dropout disabled. Returns
    /// one hypothesis per batch row (stops at `eos` or `max_steps`).
    pub fn greedy_decode(
        &self, batch: &PairBatch, eos: u32, max_steps: usize,
    ) -> Vec<Vec<u32>> {
        let cfg = &self.cfg;
        let (h, l) = (cfg.hidden, cfg.layers);
        let b = batch.b;
        let s_max = batch.src_max;
        let ones = Mask::Ones { h };
        let mut timer = PhaseTimer::new();

        // encoder
        let mut ehs: Vec<Vec<f32>> = (0..l).map(|_| vec![0.0; b * h]).collect();
        let mut ecs: Vec<Vec<f32>> = (0..l).map(|_| vec![0.0; b * h]).collect();
        let mut he = vec![0.0f32; b * s_max * h];
        for t in 0..s_max {
            let ids: Vec<i32> = (0..b).map(|r| batch.src[r * s_max + t]).collect();
            let mut inp = vec![0.0f32; b * h];
            self.src_emb.fwd(&ids, &mut inp);
            for li in 0..l {
                let (hn, cn, _) = cell_fwd(
                    &self.enc[li], &inp, &ehs[li], &ecs[li], &ones, &ones, b, &mut timer,
                );
                ehs[li] = hn.clone();
                ecs[li] = cn;
                inp = hn;
            }
            for r in 0..b {
                he[(r * s_max + t) * h..(r * s_max + t + 1) * h]
                    .copy_from_slice(&inp[r * h..(r + 1) * h]);
            }
        }

        // decoder, greedy
        let mut dhs = ehs;
        let mut dcs = ecs;
        let mut cur: Vec<i32> = vec![crate::data::vocab::BOS as i32; b];
        let mut hyps: Vec<Vec<u32>> = vec![Vec::new(); b];
        let mut done = vec![false; b];
        for _ in 0..max_steps {
            let mut inp = vec![0.0f32; b * h];
            self.tgt_emb.fwd(&cur, &mut inp);
            for li in 0..l {
                let (hn, cn, _) = cell_fwd(
                    &self.dec[li], &inp, &dhs[li], &dcs[li], &ones, &ones, b, &mut timer,
                );
                dhs[li] = hn.clone();
                dcs[li] = cn;
                inp = hn;
            }
            let mut hhat = vec![0.0f32; b * h];
            self.attn.fwd(&inp, &he, &batch.src_len, b, s_max, &mut timer, &mut hhat);
            let mut logits = vec![0.0f32; b * cfg.tgt_vocab];
            self.proj.fwd(&hhat, &ones, b, &mut timer, &mut logits);
            for r in 0..b {
                if done[r] {
                    cur[r] = eos as i32;
                    continue;
                }
                let row = &logits[r * cfg.tgt_vocab..(r + 1) * cfg.tgt_vocab];
                let best = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as u32)
                    .unwrap();
                if best == eos {
                    done[r] = true;
                } else {
                    hyps[r].push(best);
                }
                cur[r] = best as i32;
            }
            if done.iter().all(|&d| d) {
                break;
            }
        }
        hyps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::batcher::PairBatcher;
    use crate::data::corpus::ParallelCorpus;
    use crate::dropout::plan::DropoutConfig;

    fn tiny_model() -> (NmtModel, XorShift64) {
        let mut rng = XorShift64::new(1);
        let cfg = NmtConfig {
            src_vocab: 40,
            tgt_vocab: 45,
            hidden: 10,
            layers: 2,
            init_scale: 0.15,
        };
        (NmtModel::init(cfg, &mut rng), rng)
    }

    fn tiny_batch() -> PairBatch {
        let pc = ParallelCorpus::new(36, 3);
        let pairs = pc.pairs(4, 3, 6, 5);
        PairBatcher::new(&pairs, 4, crate::data::vocab::BOS, crate::data::vocab::EOS)
            .batches()[0]
            .clone()
    }

    #[test]
    fn initial_loss_near_ln_v() {
        let (m, _) = tiny_model();
        let batch = tiny_batch();
        let mut planner = MaskPlanner::new(DropoutConfig::none(), 7);
        let mut grads = NmtGrads::zeros(&m);
        let mut timer = PhaseTimer::new();
        let loss = m.train_batch(&batch, &mut planner, &mut grads, &mut timer);
        assert!((loss - (45f64).ln()).abs() < 0.6, "loss={loss}");
        assert!(timer.fp > std::time::Duration::ZERO);
        assert!(timer.wg > std::time::Duration::ZERO);
    }

    #[test]
    fn grads_finite_difference_spot_check() {
        let (m, _) = tiny_model();
        let batch = tiny_batch();
        // Fixed dropout plan via a reseeded planner each call.
        let loss_of = |m: &NmtModel| {
            let mut planner = MaskPlanner::new(DropoutConfig::nr_st(0.3), 42);
            let mut g = NmtGrads::zeros(m);
            let mut t = PhaseTimer::new();
            m.train_batch(&batch, &mut planner, &mut g, &mut t)
        };
        let mut grads = NmtGrads::zeros(&m);
        {
            let mut planner = MaskPlanner::new(DropoutConfig::nr_st(0.3), 42);
            let mut t = PhaseTimer::new();
            m.train_batch(&batch, &mut planner, &mut grads, &mut t);
        }
        let eps = 1e-2f32;
        // buffers: 0=src_emb, 1..7 enc, 7=tgt_emb, 8..14 dec, 14=wc, 16=proj_w
        for (buf_idx, coord) in [(0usize, 11usize), (2, 5), (7, 3), (9, 8), (14, 2), (16, 1)] {
            let analytic = grads.buffers_mut()[buf_idx][coord];
            let mut mp = m.clone();
            mp.buffers_mut()[buf_idx][coord] += eps;
            let mut mm = m.clone();
            mm.buffers_mut()[buf_idx][coord] -= eps;
            let num = ((loss_of(&mp) - loss_of(&mm)) / (2.0 * eps as f64)) as f32;
            assert!(
                (analytic - num).abs() < 4e-2 * (1.0 + num.abs()),
                "buffer {buf_idx}[{coord}]: analytic {analytic} vs numeric {num}"
            );
        }
    }

    #[test]
    fn sgd_learns_the_transduction() {
        // Memorization-scale check: a handful of short pairs must be
        // drivable to low loss (generalization is tested at experiment
        // scale by examples/nmt_iwslt.rs).
        let (mut m, _) = tiny_model();
        let pc = ParallelCorpus::new(36, 3);
        let pairs = pc.pairs(8, 3, 5, 9);
        let pb = PairBatcher::new(&pairs, 8, crate::data::vocab::BOS, crate::data::vocab::EOS);
        let mut planner = MaskPlanner::new(DropoutConfig::nr_st(0.1), 13);
        let mut grads = NmtGrads::zeros(&m);
        let mut timer = PhaseTimer::new();
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..40 {
            for batch in pb.batches() {
                let loss = m.train_batch(batch, &mut planner, &mut grads, &mut timer);
                if first.is_none() {
                    first = Some(loss);
                }
                last = loss;
                for (p, g) in m.buffers_mut().into_iter().zip(grads.buffers_mut()) {
                    for (pv, gv) in p.iter_mut().zip(g.iter()) {
                        *pv -= 0.7 * gv;
                    }
                }
            }
        }
        assert!(last < first.unwrap() * 0.8,
                "NMT loss did not drop: {:?} -> {last}", first);
    }

    #[test]
    fn greedy_decode_produces_bounded_hyps() {
        let (m, _) = tiny_model();
        let batch = tiny_batch();
        let hyps = m.greedy_decode(&batch, crate::data::vocab::EOS, 12);
        assert_eq!(hyps.len(), batch.b);
        for hyp in &hyps {
            assert!(hyp.len() <= 12);
            assert!(hyp.iter().all(|&t| (t as usize) < m.cfg.tgt_vocab));
        }
    }
}
