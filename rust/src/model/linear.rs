//! Fully-connected projection layer with optional structured input
//! dropout. The paper's speedup measurements include "the LSTM and FC
//! layers" (§4) — the pre-softmax projection consumes the output-dropout
//! mask, so its GEMM also takes the compacted FP/BP/WG paths.

use crate::dropout::mask::Mask;
use crate::dropout::rng::XorShift64;
use crate::gemm::backend;
use crate::gemm::sparse::{bp_matmul_ws, fp_matmul_acc_ws, wg_matmul_acc_ws, SparseScratch};
use crate::train::timing::{Phase, PhaseTimer};

/// `y = (x ⊙ mask) @ w + b` with `w: [din, dout]`.
#[derive(Debug, Clone)]
pub struct Linear {
    pub din: usize,
    pub dout: usize,
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

/// Gradients for [`Linear`].
#[derive(Debug, Clone)]
pub struct LinearGrads {
    pub dw: Vec<f32>,
    pub db: Vec<f32>,
}

impl LinearGrads {
    pub fn zeros(l: &Linear) -> LinearGrads {
        LinearGrads { dw: vec![0.0; l.w.len()], db: vec![0.0; l.b.len()] }
    }

    pub fn zero(&mut self) {
        self.dw.fill(0.0);
        self.db.fill(0.0);
    }
}

/// Forward residual of the allocating [`Linear::fwd`] API. The workspace
/// path ([`Linear::fwd_ws`] / [`Linear::bwd_ws`]) keeps the masked input in
/// a caller buffer and re-reads the mask from the caller's plan instead —
/// no clone, no per-step allocation.
#[derive(Debug, Clone)]
pub struct LinearCache {
    /// Masked input `x ⊙ m`, `[b, din]`.
    pub xd: Vec<f32>,
    pub mask: Mask,
}

impl Linear {
    pub fn init(din: usize, dout: usize, s: f32, rng: &mut XorShift64) -> Linear {
        Linear {
            din,
            dout,
            w: (0..din * dout).map(|_| rng.uniform(-s, s)).collect(),
            b: vec![0.0; dout],
        }
    }

    /// Allocation-free forward: the masked input is materialized into `xd`
    /// (caller buffer, capacity reused) and logits into `out`. The mask is
    /// *not* cloned — pass the same mask back to [`Linear::bwd_ws`]. FP
    /// GEMM is compacted when the mask is structured.
    #[allow(clippy::too_many_arguments)]
    pub fn fwd_ws(
        &self, x: &[f32], mask: &Mask, bsz: usize, timer: &mut PhaseTimer,
        xd: &mut Vec<f32>, out: &mut [f32], scratch: &mut SparseScratch,
    ) {
        assert_eq!(x.len(), bsz * self.din);
        assert_eq!(out.len(), bsz * self.dout);
        let be = backend::global();
        xd.clear();
        xd.extend_from_slice(x);
        mask.apply(xd, bsz);
        timer.time(Phase::Fp, || {
            match mask {
                Mask::Column(cm) if cm.kept() < cm.h => {
                    out.fill(0.0);
                    fp_matmul_acc_ws(be.as_ref(), xd, &self.w, &cm.keep, 1.0,
                                     bsz, self.din, self.dout, out, scratch);
                }
                _ => be.as_ref().matmul(xd, &self.w, out, bsz, self.din, self.dout),
            }
            for r in 0..bsz {
                for j in 0..self.dout {
                    out[r * self.dout + j] += self.b[j];
                }
            }
        });
    }

    /// Allocation-free backward over `fwd_ws` residuals: `xd` is the
    /// masked input that call produced, `mask` the same mask. Writes `dx`
    /// (masked) into the caller buffer and accumulates `dw`/`db`.
    #[allow(clippy::too_many_arguments)]
    pub fn bwd_ws(
        &self, xd: &[f32], mask: &Mask, dy: &[f32], bsz: usize,
        grads: &mut LinearGrads, timer: &mut PhaseTimer,
        dx: &mut [f32], scratch: &mut SparseScratch,
    ) {
        assert_eq!(dy.len(), bsz * self.dout);
        assert_eq!(dx.len(), bsz * self.din);
        let be = backend::global();
        timer.time(Phase::Bp, || match mask {
            Mask::Column(cm) if cm.kept() < cm.h => {
                bp_matmul_ws(be.as_ref(), dy, &self.w, &cm.keep, cm.scale,
                             bsz, self.din, self.dout, dx, scratch);
            }
            Mask::Ones { .. } => {
                be.as_ref().matmul_a_bt(dy, &self.w, dx, bsz, self.dout, self.din);
            }
            m => {
                be.as_ref().matmul_a_bt(dy, &self.w, dx, bsz, self.dout, self.din);
                m.apply(dx, bsz);
            }
        });
        timer.time(Phase::Wg, || {
            match mask {
                Mask::Column(cm) if cm.kept() < cm.h => {
                    wg_matmul_acc_ws(be.as_ref(), xd, dy, &cm.keep, 1.0,
                                     bsz, self.din, self.dout, &mut grads.dw, scratch);
                }
                _ => {
                    let tmp = scratch.dense(self.din * self.dout);
                    be.as_ref().matmul_at_b(xd, dy, tmp, bsz, self.din, self.dout);
                    for (d, t) in grads.dw.iter_mut().zip(tmp.iter()) {
                        *d += *t;
                    }
                }
            }
            for r in 0..bsz {
                for j in 0..self.dout {
                    grads.db[j] += dy[r * self.dout + j];
                }
            }
        });
    }

    /// Forward with input mask (use `Mask::Ones` for no dropout) — the
    /// allocating convenience API over [`Linear::fwd_ws`].
    pub fn fwd(
        &self, x: &[f32], mask: &Mask, bsz: usize,
        timer: &mut PhaseTimer, out: &mut [f32],
    ) -> LinearCache {
        let mut xd = Vec::new();
        let mut scratch = SparseScratch::new();
        self.fwd_ws(x, mask, bsz, timer, &mut xd, out, &mut scratch);
        LinearCache { xd, mask: mask.clone() }
    }

    /// Backward: returns `dx` (masked) and accumulates `dw`/`db` — the
    /// allocating convenience API over [`Linear::bwd_ws`].
    pub fn bwd(
        &self, cache: &LinearCache, dy: &[f32], bsz: usize,
        grads: &mut LinearGrads, timer: &mut PhaseTimer,
    ) -> Vec<f32> {
        let mut dx = vec![0.0f32; bsz * self.din];
        let mut scratch = SparseScratch::new();
        self.bwd_ws(&cache.xd, &cache.mask, dy, bsz, grads, timer, &mut dx, &mut scratch);
        dx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dropout::mask::ColumnMask;
    use crate::gemm::matmul;
    use crate::util::prop;

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
                    "mismatch at {i}: {x} vs {y}");
        }
    }

    #[test]
    fn masked_fwd_matches_dense() {
        prop::for_all("linear fwd structured == dense", |rng| {
            let b = prop::usize_in(rng, 1, 6);
            let din = prop::usize_in(rng, 2, 24);
            let dout = prop::usize_in(rng, 1, 16);
            let l = Linear::init(din, dout, 0.5, rng);
            let x = prop::vec_f32(rng, b * din, 1.0);
            let mask = Mask::Column(ColumnMask::sample(rng, din, 0.5));
            let mut t = PhaseTimer::new();
            let mut got = vec![0.0; b * dout];
            l.fwd(&x, &mask, b, &mut t, &mut got);

            let mut xd = x.clone();
            mask.apply(&mut xd, b);
            let mut want = vec![0.0; b * dout];
            matmul(&xd, &l.w, &mut want, b, din, dout);
            for r in 0..b {
                for j in 0..dout {
                    want[r * dout + j] += l.b[j];
                }
            }
            assert_close(&got, &want, 1e-4);
        });
    }

    #[test]
    fn bwd_finite_difference() {
        let mut rng = XorShift64::new(5);
        let (b, din, dout) = (2, 6, 4);
        let l = Linear::init(din, dout, 0.5, &mut rng);
        let x = prop::vec_f32(&mut rng, b * din, 1.0);
        let mask = Mask::Column(ColumnMask::sample(&mut rng, din, 0.5));
        let mut t = PhaseTimer::new();

        // Loss = 0.5 * sum(y^2).
        let loss = |l: &Linear, x: &[f32]| -> f64 {
            let mut tt = PhaseTimer::new();
            let mut y = vec![0.0; b * dout];
            l.fwd(x, &mask, b, &mut tt, &mut y);
            0.5 * y.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
        };

        let mut y = vec![0.0; b * dout];
        let cache = l.fwd(&x, &mask, b, &mut t, &mut y);
        let mut grads = LinearGrads::zeros(&l);
        let dx = l.bwd(&cache, &y, b, &mut grads, &mut t);

        let eps = 1e-3;
        for idx in [0usize, b * din - 1] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let num = ((loss(&l, &xp) - loss(&l, &xm)) / (2.0 * eps as f64)) as f32;
            assert!((dx[idx] - num).abs() < 1e-2 * (1.0 + num.abs()),
                    "dx[{idx}] {} vs {num}", dx[idx]);
        }
        for idx in [0usize, din * dout - 1] {
            let mut lp = l.clone();
            lp.w[idx] += eps;
            let mut lm = l.clone();
            lm.w[idx] -= eps;
            let num = ((loss(&lp, &x) - loss(&lm, &x)) / (2.0 * eps as f64)) as f32;
            assert!((grads.dw[idx] - num).abs() < 1e-2 * (1.0 + num.abs()),
                    "dw[{idx}] {} vs {num}", grads.dw[idx]);
        }
        for idx in [0usize, dout - 1] {
            let mut lp = l.clone();
            lp.b[idx] += eps;
            let mut lm = l.clone();
            lm.b[idx] -= eps;
            let num = ((loss(&lp, &x) - loss(&lm, &x)) / (2.0 * eps as f64)) as f32;
            assert!((grads.db[idx] - num).abs() < 1e-2 * (1.0 + num.abs()),
                    "db[{idx}] {} vs {num}", grads.db[idx]);
        }
    }
}
