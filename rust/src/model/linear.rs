//! Fully-connected projection layer with optional structured input
//! dropout. The paper's speedup measurements include "the LSTM and FC
//! layers" (§4) — the pre-softmax projection consumes the output-dropout
//! mask, so its GEMM also takes the compacted FP/BP/WG paths.

use crate::dropout::mask::{ColumnMask, Mask};
use crate::dropout::rng::XorShift64;
use crate::gemm::{matmul, matmul_a_bt, matmul_at_b};
use crate::gemm::sparse::{bp_matmul, fp_matmul, wg_matmul_acc};
use crate::train::timing::{Phase, PhaseTimer};

/// `y = (x ⊙ mask) @ w + b` with `w: [din, dout]`.
#[derive(Debug, Clone)]
pub struct Linear {
    pub din: usize,
    pub dout: usize,
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

/// Gradients for [`Linear`].
#[derive(Debug, Clone)]
pub struct LinearGrads {
    pub dw: Vec<f32>,
    pub db: Vec<f32>,
}

impl LinearGrads {
    pub fn zeros(l: &Linear) -> LinearGrads {
        LinearGrads { dw: vec![0.0; l.w.len()], db: vec![0.0; l.b.len()] }
    }

    pub fn zero(&mut self) {
        self.dw.fill(0.0);
        self.db.fill(0.0);
    }
}

/// Forward residual.
#[derive(Debug, Clone)]
pub struct LinearCache {
    /// Masked input `x ⊙ m`, `[b, din]`.
    pub xd: Vec<f32>,
    pub mask: Mask,
}

fn unit_mask(m: &ColumnMask) -> ColumnMask {
    ColumnMask { h: m.h, keep: m.keep.clone(), scale: 1.0 }
}

impl Linear {
    pub fn init(din: usize, dout: usize, s: f32, rng: &mut XorShift64) -> Linear {
        Linear {
            din,
            dout,
            w: (0..din * dout).map(|_| rng.uniform(-s, s)).collect(),
            b: vec![0.0; dout],
        }
    }

    /// Forward with input mask (use `Mask::Ones` for no dropout). FP GEMM
    /// is compacted when the mask is structured.
    pub fn fwd(
        &self, x: &[f32], mask: &Mask, bsz: usize,
        timer: &mut PhaseTimer, out: &mut [f32],
    ) -> LinearCache {
        assert_eq!(x.len(), bsz * self.din);
        assert_eq!(out.len(), bsz * self.dout);
        let mut xd = x.to_vec();
        mask.apply(&mut xd, bsz);
        timer.time(Phase::Fp, || {
            match mask {
                Mask::Column(cm) if cm.kept() < cm.h => {
                    fp_matmul(&xd, &self.w, &unit_mask(cm), bsz, self.dout, out);
                }
                _ => matmul(&xd, &self.w, out, bsz, self.din, self.dout),
            }
            for r in 0..bsz {
                for j in 0..self.dout {
                    out[r * self.dout + j] += self.b[j];
                }
            }
        });
        LinearCache { xd, mask: mask.clone() }
    }

    /// Backward: returns `dx` (masked) and accumulates `dw`/`db`.
    pub fn bwd(
        &self, cache: &LinearCache, dy: &[f32], bsz: usize,
        grads: &mut LinearGrads, timer: &mut PhaseTimer,
    ) -> Vec<f32> {
        assert_eq!(dy.len(), bsz * self.dout);
        let mut dx = vec![0.0f32; bsz * self.din];
        timer.time(Phase::Bp, || match &cache.mask {
            Mask::Column(cm) if cm.kept() < cm.h => {
                bp_matmul(dy, &self.w, cm, bsz, self.dout, &mut dx);
            }
            Mask::Ones { .. } => {
                matmul_a_bt(dy, &self.w, &mut dx, bsz, self.dout, self.din);
            }
            m => {
                matmul_a_bt(dy, &self.w, &mut dx, bsz, self.dout, self.din);
                m.apply(&mut dx, bsz);
            }
        });
        timer.time(Phase::Wg, || {
            match &cache.mask {
                Mask::Column(cm) if cm.kept() < cm.h => {
                    wg_matmul_acc(&cache.xd, dy, &unit_mask(cm), bsz, self.dout,
                                  &mut grads.dw);
                }
                _ => {
                    let mut tmp = vec![0.0f32; self.din * self.dout];
                    matmul_at_b(&cache.xd, dy, &mut tmp, bsz, self.din, self.dout);
                    for (d, t) in grads.dw.iter_mut().zip(&tmp) {
                        *d += t;
                    }
                }
            }
            for r in 0..bsz {
                for j in 0..self.dout {
                    grads.db[j] += dy[r * self.dout + j];
                }
            }
        });
        dx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
                    "mismatch at {i}: {x} vs {y}");
        }
    }

    #[test]
    fn masked_fwd_matches_dense() {
        prop::for_all("linear fwd structured == dense", |rng| {
            let b = prop::usize_in(rng, 1, 6);
            let din = prop::usize_in(rng, 2, 24);
            let dout = prop::usize_in(rng, 1, 16);
            let l = Linear::init(din, dout, 0.5, rng);
            let x = prop::vec_f32(rng, b * din, 1.0);
            let mask = Mask::Column(ColumnMask::sample(rng, din, 0.5));
            let mut t = PhaseTimer::new();
            let mut got = vec![0.0; b * dout];
            l.fwd(&x, &mask, b, &mut t, &mut got);

            let mut xd = x.clone();
            mask.apply(&mut xd, b);
            let mut want = vec![0.0; b * dout];
            matmul(&xd, &l.w, &mut want, b, din, dout);
            for r in 0..b {
                for j in 0..dout {
                    want[r * dout + j] += l.b[j];
                }
            }
            assert_close(&got, &want, 1e-4);
        });
    }

    #[test]
    fn bwd_finite_difference() {
        let mut rng = XorShift64::new(5);
        let (b, din, dout) = (2, 6, 4);
        let l = Linear::init(din, dout, 0.5, &mut rng);
        let x = prop::vec_f32(&mut rng, b * din, 1.0);
        let mask = Mask::Column(ColumnMask::sample(&mut rng, din, 0.5));
        let mut t = PhaseTimer::new();

        // Loss = 0.5 * sum(y^2).
        let loss = |l: &Linear, x: &[f32]| -> f64 {
            let mut tt = PhaseTimer::new();
            let mut y = vec![0.0; b * dout];
            l.fwd(x, &mask, b, &mut tt, &mut y);
            0.5 * y.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
        };

        let mut y = vec![0.0; b * dout];
        let cache = l.fwd(&x, &mask, b, &mut t, &mut y);
        let mut grads = LinearGrads::zeros(&l);
        let dx = l.bwd(&cache, &y, b, &mut grads, &mut t);

        let eps = 1e-3;
        for idx in [0usize, b * din - 1] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let num = ((loss(&l, &xp) - loss(&l, &xm)) / (2.0 * eps as f64)) as f32;
            assert!((dx[idx] - num).abs() < 1e-2 * (1.0 + num.abs()),
                    "dx[{idx}] {} vs {num}", dx[idx]);
        }
        for idx in [0usize, din * dout - 1] {
            let mut lp = l.clone();
            lp.w[idx] += eps;
            let mut lm = l.clone();
            lm.w[idx] -= eps;
            let num = ((loss(&lp, &x) - loss(&lm, &x)) / (2.0 * eps as f64)) as f32;
            assert!((grads.dw[idx] - num).abs() < 1e-2 * (1.0 + num.abs()),
                    "dw[{idx}] {} vs {num}", grads.dw[idx]);
        }
        for idx in [0usize, dout - 1] {
            let mut lp = l.clone();
            lp.b[idx] += eps;
            let mut lm = l.clone();
            lm.b[idx] -= eps;
            let num = ((loss(&lp, &x) - loss(&lm, &x)) / (2.0 * eps as f64)) as f32;
            assert!((grads.db[idx] - num).abs() < 1e-2 * (1.0 + num.abs()),
                    "db[{idx}] {} vs {num}", grads.db[idx]);
        }
    }
}
