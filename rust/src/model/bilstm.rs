//! Bidirectional LSTM layer — the backbone of the paper's §4.3 NER model
//! (Ma & Hovy, 2016). A forward and a backward LSTM run over the sequence;
//! their outputs are concatenated per time step. Structured dropout is
//! applied per direction (the paper adds RH dropout "to both the forward
//! and reverse directions of BiLSTM").
//!
//! Both directions run on the unified [`crate::rnn`] runtime — the reverse
//! direction is the same [`StackedLstm`] loop under
//! [`Direction::Reversed`], so there is no hand-rolled time-reversed BPTT
//! left here. Each direction owns a [`Workspace`] (its own tape); the
//! shared step inputs and the concatenated outputs live in caller buffers.

use crate::dropout::plan::StepMasks;
use crate::model::lstm::{LstmGrads, LstmParams};
use crate::rnn::{DirMasks, Direction, StackedLstm, StepBufs, Workspace};
use crate::train::timing::PhaseTimer;

/// One BiLSTM layer: independent forward/backward direction parameters.
#[derive(Debug, Clone)]
pub struct BiLstm {
    pub fwd: LstmParams,
    pub bwd: LstmParams,
}

/// Gradients for [`BiLstm`].
#[derive(Debug, Clone)]
pub struct BiLstmGrads {
    pub fwd: LstmGrads,
    pub bwd: LstmGrads,
}

impl BiLstmGrads {
    pub fn zeros(p: &BiLstm) -> BiLstmGrads {
        BiLstmGrads { fwd: LstmGrads::zeros(&p.fwd), bwd: LstmGrads::zeros(&p.bwd) }
    }

    pub fn zero(&mut self) {
        self.fwd.zero();
        self.bwd.zero();
    }
}

/// Preallocated working memory for one [`BiLstm`]: a sequence-runtime
/// workspace (tape + scratch) per direction, plus the per-direction head
/// gradient buffers that split the concatenated `[b, 2h]` output gradient.
#[derive(Debug, Default)]
pub struct BiLstmWs {
    f: Workspace,
    r: Workspace,
    dtop_f: StepBufs,
    dtop_r: StepBufs,
}

impl BiLstmWs {
    pub fn new() -> BiLstmWs {
        BiLstmWs::default()
    }
}

impl BiLstm {
    pub fn init(dx: usize, h: usize, s: f32, rng: &mut crate::dropout::rng::XorShift64) -> BiLstm {
        BiLstm {
            fwd: LstmParams::init(dx, h, s, rng),
            bwd: LstmParams::init(dx, h, s, rng),
        }
    }

    /// Run over the first `t_len` step inputs in `xs` (`[b, dx]` each).
    /// `masks[t]` supplies `mx[0]` (shared input mask) and `mh[0]`/`mh[1]`
    /// (per-direction RH masks; callers plan `layers = 2` so both exist).
    /// Concatenated outputs (`[b, 2h]` per step) are written into `outs`;
    /// the BPTT residuals stay on the two direction tapes in `ws`.
    #[allow(clippy::too_many_arguments)]
    pub fn fwd_seq(
        &self, xs: &StepBufs, masks: &[StepMasks], t_len: usize, b: usize,
        ws: &mut BiLstmWs, outs: &mut StepBufs, timer: &mut PhaseTimer,
    ) {
        assert_eq!(masks.len(), t_len);
        let h = self.fwd.h;
        let rt_f = StackedLstm::new(std::slice::from_ref(&self.fwd));
        rt_f.forward(&mut ws.f, xs, &DirMasks { steps: masks, mh_index: 0 },
                     t_len, b, None, Direction::Forward, timer);
        let rt_r = StackedLstm::new(std::slice::from_ref(&self.bwd));
        rt_r.forward(&mut ws.r, xs, &DirMasks { steps: masks, mh_index: 1 },
                     t_len, b, None, Direction::Reversed, timer);

        outs.ensure(t_len, b * 2 * h);
        for t in 0..t_len {
            let hf = ws.f.tape.h_top(t);
            let hb = ws.r.tape.h_top(t);
            let o = outs.buf_mut(t);
            for r in 0..b {
                o[r * 2 * h..r * 2 * h + h].copy_from_slice(&hf[r * h..(r + 1) * h]);
                o[r * 2 * h + h..(r + 1) * 2 * h].copy_from_slice(&hb[r * h..(r + 1) * h]);
            }
        }
    }

    /// Backward over the whole sequence. `douts` holds `[b, 2h]` output
    /// gradients per step; per-step input gradients are *accumulated* into
    /// `dxs` (sized and zeroed here). Must follow a matching [`Self::fwd_seq`]
    /// on the same `ws`.
    #[allow(clippy::too_many_arguments)]
    pub fn bwd_seq(
        &self, masks: &[StepMasks], t_len: usize, b: usize, douts: &StepBufs,
        ws: &mut BiLstmWs, grads: &mut BiLstmGrads, dxs: &mut StepBufs,
        timer: &mut PhaseTimer,
    ) {
        let h = self.fwd.h;
        let dx_dim = self.fwd.dx;
        dxs.ensure(t_len, b * dx_dim);
        dxs.zero(t_len);

        // Split the concatenated output gradient into per-direction tops.
        ws.dtop_f.ensure(t_len, b * h);
        ws.dtop_r.ensure(t_len, b * h);
        for t in 0..t_len {
            let d = douts.buf(t);
            let df = ws.dtop_f.buf_mut(t);
            for r in 0..b {
                df[r * h..(r + 1) * h].copy_from_slice(&d[r * 2 * h..r * 2 * h + h]);
            }
            let dr = ws.dtop_r.buf_mut(t);
            for r in 0..b {
                dr[r * h..(r + 1) * h].copy_from_slice(&d[r * 2 * h + h..(r + 1) * 2 * h]);
            }
        }

        let rt_f = StackedLstm::new(std::slice::from_ref(&self.fwd));
        rt_f.backward(&mut ws.f, &ws.dtop_f, &DirMasks { steps: masks, mh_index: 0 },
                      t_len, b, None, std::slice::from_mut(&mut grads.fwd),
                      Direction::Forward, timer, |t, dx| {
                          let acc = dxs.buf_mut(t);
                          for (a, v) in acc.iter_mut().zip(dx) {
                              *a += *v;
                          }
                      });
        let rt_r = StackedLstm::new(std::slice::from_ref(&self.bwd));
        rt_r.backward(&mut ws.r, &ws.dtop_r, &DirMasks { steps: masks, mh_index: 1 },
                      t_len, b, None, std::slice::from_mut(&mut grads.bwd),
                      Direction::Reversed, timer, |t, dx| {
                          let acc = dxs.buf_mut(t);
                          for (a, v) in acc.iter_mut().zip(dx) {
                              *a += *v;
                          }
                      });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dropout::plan::{DropoutConfig, MaskPlanner};
    use crate::dropout::rng::XorShift64;
    use crate::util::prop;

    fn step_inputs(rng: &mut XorShift64, t_len: usize, n: usize) -> (StepBufs, Vec<Vec<f32>>) {
        let raw: Vec<Vec<f32>> = (0..t_len).map(|_| prop::vec_f32(rng, n, 0.8)).collect();
        let mut bufs = StepBufs::new();
        bufs.ensure(t_len, n);
        for (t, x) in raw.iter().enumerate() {
            bufs.buf_mut(t).copy_from_slice(x);
        }
        (bufs, raw)
    }

    fn ner_style_masks(t_len: usize, b: usize, dx: usize, h: usize) -> Vec<StepMasks> {
        let mut planner = MaskPlanner::new(DropoutConfig::none(), 2);
        let plan = planner.plan(t_len, b, h, 2);
        let mut planner_x = MaskPlanner::new(DropoutConfig::none(), 2);
        let plan_x = planner_x.plan(t_len, b, dx, 2);
        let mut steps = plan.steps.clone();
        for (s, sx) in steps.iter_mut().zip(&plan_x.steps) {
            s.mx = sx.mx.clone();
        }
        steps
    }

    #[test]
    fn output_concatenates_directions() {
        let mut rng = XorShift64::new(1);
        let (b, dx, h, t_len) = (2, 5, 4, 3);
        let bi = BiLstm::init(dx, h, 0.3, &mut rng);
        let (xs, _) = step_inputs(&mut rng, t_len, b * dx);
        let steps = ner_style_masks(t_len, b, dx, h);
        let mut ws = BiLstmWs::new();
        let mut outs = StepBufs::new();
        let mut timer = PhaseTimer::new();
        bi.fwd_seq(&xs, &steps, t_len, b, &mut ws, &mut outs, &mut timer);
        assert_eq!(outs.buf(0).len(), b * 2 * h);
        // Forward half comes from the forward tape, reverse half from the
        // reverse tape.
        for t in 0..t_len {
            let o = outs.buf(t);
            for r in 0..b {
                assert_eq!(&o[r * 2 * h..r * 2 * h + h],
                           &ws.f.tape.h_top(t)[r * h..(r + 1) * h]);
                assert_eq!(&o[r * 2 * h + h..(r + 1) * 2 * h],
                           &ws.r.tape.h_top(t)[r * h..(r + 1) * h]);
            }
        }
    }

    #[test]
    fn bwd_finite_difference() {
        let mut rng = XorShift64::new(2);
        let (b, dx, h, t_len) = (2, 4, 3, 3);
        let bi = BiLstm::init(dx, h, 0.4, &mut rng);
        let (xs, raw_xs) = step_inputs(&mut rng, t_len, b * dx);
        let steps = ner_style_masks(t_len, b, dx, h);

        let loss = |bi: &BiLstm, raw: &[Vec<f32>]| -> f64 {
            let mut t = PhaseTimer::new();
            let mut ws = BiLstmWs::new();
            let mut xb = StepBufs::new();
            xb.ensure(t_len, b * dx);
            for (ti, x) in raw.iter().enumerate() {
                xb.buf_mut(ti).copy_from_slice(x);
            }
            let mut outs = StepBufs::new();
            bi.fwd_seq(&xb, &steps, t_len, b, &mut ws, &mut outs, &mut t);
            (0..t_len)
                .map(|ti| {
                    outs.buf(ti)
                        .iter()
                        .map(|&v| 0.5 * (v as f64) * (v as f64))
                        .sum::<f64>()
                })
                .sum()
        };

        let mut timer = PhaseTimer::new();
        let mut ws = BiLstmWs::new();
        let mut outs = StepBufs::new();
        bi.fwd_seq(&xs, &steps, t_len, b, &mut ws, &mut outs, &mut timer);
        let mut grads = BiLstmGrads::zeros(&bi);
        let mut dxs = StepBufs::new();
        // dL/douts = outs for L = 0.5*Σ outs².
        bi.bwd_seq(&steps, t_len, b, &outs, &mut ws, &mut grads, &mut dxs, &mut timer);

        let eps = 1e-3f32;
        for t in 0..t_len {
            for idx in [0usize, b * dx - 1] {
                let mut xp = raw_xs.clone();
                xp[t][idx] += eps;
                let mut xm = raw_xs.clone();
                xm[t][idx] -= eps;
                let num = ((loss(&bi, &xp) - loss(&bi, &xm)) / (2.0 * eps as f64)) as f32;
                assert!((dxs.buf(t)[idx] - num).abs() < 2e-2 * (1.0 + num.abs()),
                        "dx[{t}][{idx}] {} vs {num}", dxs.buf(t)[idx]);
            }
        }
        // weight grad spot check (forward-direction U)
        for idx in [0usize, bi.fwd.u.len() - 1] {
            let mut bp = bi.clone();
            bp.fwd.u[idx] += eps;
            let mut bm = bi.clone();
            bm.fwd.u[idx] -= eps;
            let num = ((loss(&bp, &raw_xs) - loss(&bm, &raw_xs)) / (2.0 * eps as f64)) as f32;
            assert!((grads.fwd.du[idx] - num).abs() < 2e-2 * (1.0 + num.abs()),
                    "dU_fwd[{idx}] {} vs {num}", grads.fwd.du[idx]);
        }
    }
}
