//! Bidirectional LSTM layer — the backbone of the paper's §4.3 NER model
//! (Ma & Hovy, 2016). A forward and a backward LSTM run over the sequence;
//! their outputs are concatenated per time step. Structured dropout is
//! applied per direction (the paper adds RH dropout "to both the forward
//! and reverse directions of BiLSTM").

use crate::dropout::plan::StepMasks;
use crate::model::lstm::{cell_bwd, cell_fwd, CellCache, LstmGrads, LstmParams};
use crate::train::timing::PhaseTimer;

/// One BiLSTM layer: independent forward/backward direction parameters.
#[derive(Debug, Clone)]
pub struct BiLstm {
    pub fwd: LstmParams,
    pub bwd: LstmParams,
}

/// Gradients for [`BiLstm`].
#[derive(Debug, Clone)]
pub struct BiLstmGrads {
    pub fwd: LstmGrads,
    pub bwd: LstmGrads,
}

impl BiLstmGrads {
    pub fn zeros(p: &BiLstm) -> BiLstmGrads {
        BiLstmGrads { fwd: LstmGrads::zeros(&p.fwd), bwd: LstmGrads::zeros(&p.bwd) }
    }

    pub fn zero(&mut self) {
        self.fwd.zero();
        self.bwd.zero();
    }
}

/// Forward residuals over a `[T]` sequence.
pub struct BiLstmCache {
    pub fwd: Vec<CellCache>,
    pub bwd: Vec<CellCache>,
    pub t_len: usize,
}

impl BiLstm {
    pub fn init(dx: usize, h: usize, s: f32, rng: &mut crate::dropout::rng::XorShift64) -> BiLstm {
        BiLstm {
            fwd: LstmParams::init(dx, h, s, rng),
            bwd: LstmParams::init(dx, h, s, rng),
        }
    }

    /// Run over `xs[t]` (`[b, dx]` each). `masks[t]` supplies `mx[0]`
    /// (shared input mask) and `mh[0]`/`mh[1]` (per-direction RH masks;
    /// callers plan `layers = 2` so both exist). Returns concatenated
    /// outputs `[t][b, 2h]` and the cache.
    pub fn fwd_seq(
        &self, xs: &[Vec<f32>], masks: &[StepMasks], b: usize,
        timer: &mut PhaseTimer,
    ) -> (Vec<Vec<f32>>, BiLstmCache) {
        let t_len = xs.len();
        let h = self.fwd.h;
        assert_eq!(masks.len(), t_len);

        let mut hf = vec![0.0f32; b * h];
        let mut cf = vec![0.0f32; b * h];
        let mut fwd_h: Vec<Vec<f32>> = Vec::with_capacity(t_len);
        let mut fwd_cache = Vec::with_capacity(t_len);
        for t in 0..t_len {
            let (hn, cn, cache) = cell_fwd(
                &self.fwd, &xs[t], &hf, &cf, &masks[t].mx[0], &masks[t].mh[0], b, timer,
            );
            hf = hn.clone();
            cf = cn;
            fwd_h.push(hn);
            fwd_cache.push(cache);
        }

        let mut hb = vec![0.0f32; b * h];
        let mut cb = vec![0.0f32; b * h];
        let mut bwd_h: Vec<Vec<f32>> = vec![Vec::new(); t_len];
        let mut bwd_cache: Vec<Option<CellCache>> = (0..t_len).map(|_| None).collect();
        for t in (0..t_len).rev() {
            let (hn, cn, cache) = cell_fwd(
                &self.bwd, &xs[t], &hb, &cb, &masks[t].mx[0], &masks[t].mh[1], b, timer,
            );
            hb = hn.clone();
            cb = cn;
            bwd_h[t] = hn;
            bwd_cache[t] = Some(cache);
        }

        let outs = (0..t_len)
            .map(|t| {
                let mut o = vec![0.0f32; b * 2 * h];
                for r in 0..b {
                    o[r * 2 * h..r * 2 * h + h]
                        .copy_from_slice(&fwd_h[t][r * h..(r + 1) * h]);
                    o[r * 2 * h + h..(r + 1) * 2 * h]
                        .copy_from_slice(&bwd_h[t][r * h..(r + 1) * h]);
                }
                o
            })
            .collect();
        let cache = BiLstmCache {
            fwd: fwd_cache,
            bwd: bwd_cache.into_iter().map(Option::unwrap).collect(),
            t_len,
        };
        (outs, cache)
    }

    /// Backward over the whole sequence. `douts[t]` is `[b, 2h]`. Returns
    /// per-step input gradients `[t][b, dx]`.
    pub fn bwd_seq(
        &self, cache: &BiLstmCache, douts: &[Vec<f32>], b: usize,
        grads: &mut BiLstmGrads, timer: &mut PhaseTimer,
    ) -> Vec<Vec<f32>> {
        let t_len = cache.t_len;
        let h = self.fwd.h;
        let dx = self.fwd.dx;
        let mut dxs: Vec<Vec<f32>> = (0..t_len).map(|_| vec![0.0f32; b * dx]).collect();

        // forward direction runs backward in time
        let mut dh_next = vec![0.0f32; b * h];
        let mut dc_next = vec![0.0f32; b * h];
        for t in (0..t_len).rev() {
            let mut dh = vec![0.0f32; b * h];
            for r in 0..b {
                dh[r * h..(r + 1) * h]
                    .copy_from_slice(&douts[t][r * 2 * h..r * 2 * h + h]);
            }
            for (dv, nv) in dh.iter_mut().zip(&dh_next) {
                *dv += nv;
            }
            let (dxv, dhp, dcp) =
                cell_bwd(&self.fwd, &cache.fwd[t], &dh, &dc_next, b, &mut grads.fwd, timer);
            dh_next = dhp;
            dc_next = dcp;
            for (a, v) in dxs[t].iter_mut().zip(&dxv) {
                *a += v;
            }
        }

        // backward direction runs forward in time
        let mut dh_next = vec![0.0f32; b * h];
        let mut dc_next = vec![0.0f32; b * h];
        for t in 0..t_len {
            let mut dh = vec![0.0f32; b * h];
            for r in 0..b {
                dh[r * h..(r + 1) * h]
                    .copy_from_slice(&douts[t][r * 2 * h + h..(r + 1) * 2 * h]);
            }
            for (dv, nv) in dh.iter_mut().zip(&dh_next) {
                *dv += nv;
            }
            let (dxv, dhp, dcp) =
                cell_bwd(&self.bwd, &cache.bwd[t], &dh, &dc_next, b, &mut grads.bwd, timer);
            dh_next = dhp;
            dc_next = dcp;
            for (a, v) in dxs[t].iter_mut().zip(&dxv) {
                *a += v;
            }
        }
        dxs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dropout::plan::{DropoutConfig, MaskPlanner};
    use crate::dropout::rng::XorShift64;
    use crate::util::prop;

    #[test]
    fn output_concatenates_directions() {
        let mut rng = XorShift64::new(1);
        let (b, dx, h, t_len) = (2, 5, 4, 3);
        let bi = BiLstm::init(dx, h, 0.3, &mut rng);
        let xs: Vec<Vec<f32>> =
            (0..t_len).map(|_| prop::vec_f32(&mut rng, b * dx, 0.8)).collect();
        let mut planner = MaskPlanner::new(DropoutConfig::none(), 2);
        let plan = planner.plan(t_len, b, h, 2);
        // input masks must match dx, not h — replan with correct widths:
        let mut planner_x = MaskPlanner::new(DropoutConfig::none(), 2);
        let plan_x = planner_x.plan(t_len, b, dx, 2);
        let mut steps = plan.steps.clone();
        for (s, sx) in steps.iter_mut().zip(&plan_x.steps) {
            s.mx = sx.mx.clone();
        }
        let mut timer = PhaseTimer::new();
        let (outs, _) = bi.fwd_seq(&xs, &steps, b, &mut timer);
        assert_eq!(outs.len(), t_len);
        assert_eq!(outs[0].len(), b * 2 * h);
    }

    #[test]
    fn bwd_finite_difference() {
        let mut rng = XorShift64::new(2);
        let (b, dx, h, t_len) = (2, 4, 3, 3);
        let bi = BiLstm::init(dx, h, 0.4, &mut rng);
        let xs: Vec<Vec<f32>> =
            (0..t_len).map(|_| prop::vec_f32(&mut rng, b * dx, 0.8)).collect();
        let mut planner = MaskPlanner::new(DropoutConfig::none(), 3);
        let plan_h = planner.plan(t_len, b, h, 2);
        let mut planner_x = MaskPlanner::new(DropoutConfig::none(), 3);
        let plan_x = planner_x.plan(t_len, b, dx, 2);
        let mut steps = plan_h.steps.clone();
        for (s, sx) in steps.iter_mut().zip(&plan_x.steps) {
            s.mx = sx.mx.clone();
        }

        let loss = |bi: &BiLstm, xs: &[Vec<f32>]| -> f64 {
            let mut t = PhaseTimer::new();
            let (outs, _) = bi.fwd_seq(xs, &steps, b, &mut t);
            outs.iter()
                .flat_map(|o| o.iter())
                .map(|&v| 0.5 * (v as f64) * (v as f64))
                .sum()
        };

        let mut timer = PhaseTimer::new();
        let (outs, cache) = bi.fwd_seq(&xs, &steps, b, &mut timer);
        let mut grads = BiLstmGrads::zeros(&bi);
        let dxs = bi.bwd_seq(&cache, &outs, b, &mut grads, &mut timer);

        let eps = 1e-3f32;
        for t in 0..t_len {
            for idx in [0usize, b * dx - 1] {
                let mut xp = xs.clone();
                xp[t][idx] += eps;
                let mut xm = xs.clone();
                xm[t][idx] -= eps;
                let num = ((loss(&bi, &xp) - loss(&bi, &xm)) / (2.0 * eps as f64)) as f32;
                assert!((dxs[t][idx] - num).abs() < 2e-2 * (1.0 + num.abs()),
                        "dx[{t}][{idx}] {} vs {num}", dxs[t][idx]);
            }
        }
        // weight grad spot check (forward-direction U)
        for idx in [0usize, bi.fwd.u.len() - 1] {
            let mut bp = bi.clone();
            bp.fwd.u[idx] += eps;
            let mut bm = bi.clone();
            bm.fwd.u[idx] -= eps;
            let num = ((loss(&bp, &xs) - loss(&bm, &xs)) / (2.0 * eps as f64)) as f32;
            assert!((grads.fwd.du[idx] - num).abs() < 2e-2 * (1.0 + num.abs()),
                    "dU_fwd[{idx}] {} vs {num}", grads.fwd.du[idx]);
        }
    }
}
