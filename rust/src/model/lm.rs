//! The Zaremba-style LSTM language model (paper §4.1) on the native
//! engine: embedding → L LSTM layers with structured dropout → output
//! dropout → projection → cross-entropy, with exact BPTT through a
//! `[T, B]` window and hidden state carried across windows.

use crate::data::batcher::LmWindow;
use crate::dropout::mask::Mask;
use crate::dropout::plan::MaskPlan;
use crate::dropout::rng::XorShift64;
use crate::model::embedding::Embedding;
use crate::model::linear::{Linear, LinearGrads};
use crate::model::lstm::{cell_bwd, cell_fwd, CellCache, LstmGrads, LstmParams};
use crate::model::softmax::{ce_bwd, ce_fwd};
use crate::train::timing::{Phase, PhaseTimer};

/// Static LM configuration (embedding size = hidden size, as in the paper).
#[derive(Debug, Clone, Copy)]
pub struct LmModelConfig {
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub init_scale: f32,
}

/// The model: parameters of all layers.
#[derive(Debug, Clone)]
pub struct LmModel {
    pub cfg: LmModelConfig,
    pub emb: Embedding,
    pub lstm: Vec<LstmParams>,
    pub proj: Linear,
}

/// Gradients matching [`LmModel`].
#[derive(Debug, Clone)]
pub struct LmGrads {
    pub demb: Vec<f32>,
    pub lstm: Vec<LstmGrads>,
    pub proj: LinearGrads,
}

impl LmGrads {
    pub fn zeros(m: &LmModel) -> LmGrads {
        LmGrads {
            demb: vec![0.0; m.emb.w.len()],
            lstm: m.lstm.iter().map(LstmGrads::zeros).collect(),
            proj: LinearGrads::zeros(&m.proj),
        }
    }

    pub fn zero(&mut self) {
        self.demb.fill(0.0);
        for g in &mut self.lstm {
            g.zero();
        }
        self.proj.zero();
    }

    /// Flat view over all gradient buffers (for clipping / updates).
    pub fn buffers_mut(&mut self) -> Vec<&mut [f32]> {
        let mut v: Vec<&mut [f32]> = vec![&mut self.demb];
        for g in &mut self.lstm {
            v.push(&mut g.dw);
            v.push(&mut g.du);
            v.push(&mut g.db);
        }
        v.push(&mut self.proj.dw);
        v.push(&mut self.proj.db);
        v
    }
}

/// Recurrent state carried across BPTT windows (truncated BPTT: detached).
#[derive(Debug, Clone)]
pub struct LmState {
    pub h: Vec<Vec<f32>>,
    pub c: Vec<Vec<f32>>,
    pub batch: usize,
}

impl LmState {
    pub fn zeros(cfg: &LmModelConfig, batch: usize) -> LmState {
        LmState {
            h: (0..cfg.layers).map(|_| vec![0.0; batch * cfg.hidden]).collect(),
            c: (0..cfg.layers).map(|_| vec![0.0; batch * cfg.hidden]).collect(),
            batch,
        }
    }

    pub fn reset(&mut self) {
        for b in self.h.iter_mut().chain(self.c.iter_mut()) {
            b.fill(0.0);
        }
    }
}

impl LmModel {
    pub fn init(cfg: LmModelConfig, rng: &mut XorShift64) -> LmModel {
        let s = cfg.init_scale;
        let emb = Embedding::init(cfg.vocab, cfg.hidden, s, rng);
        let lstm = (0..cfg.layers)
            .map(|_| LstmParams::init(cfg.hidden, cfg.hidden, s, rng))
            .collect();
        let proj = Linear::init(cfg.hidden, cfg.vocab, s, rng);
        LmModel { cfg, emb, lstm, proj }
    }

    pub fn param_count(&self) -> usize {
        self.emb.w.len()
            + self.lstm.iter().map(LstmParams::numel).sum::<usize>()
            + self.proj.w.len()
            + self.proj.b.len()
    }

    /// Flat view over all parameter buffers, ordered to match
    /// [`LmGrads::buffers_mut`] and the XLA manifest parameter order.
    pub fn buffers_mut(&mut self) -> Vec<&mut [f32]> {
        let mut v: Vec<&mut [f32]> = vec![&mut self.emb.w];
        for p in &mut self.lstm {
            v.push(&mut p.w);
            v.push(&mut p.u);
            v.push(&mut p.b);
        }
        v.push(&mut self.proj.w);
        v.push(&mut self.proj.b);
        v
    }

    pub fn buffers(&self) -> Vec<&[f32]> {
        let mut v: Vec<&[f32]> = vec![&self.emb.w];
        for p in &self.lstm {
            v.push(&p.w);
            v.push(&p.u);
            v.push(&p.b);
        }
        v.push(&self.proj.w);
        v.push(&self.proj.b);
        v
    }

    /// One training window: forward + backward with exact BPTT, returning
    /// the mean per-token NLL. Gradients accumulate into `grads` (zeroed
    /// here); recurrent state in `state` is updated (detached) for the
    /// next window.
    pub fn train_window(
        &self,
        win: &LmWindow,
        plan: &MaskPlan,
        state: &mut LmState,
        grads: &mut LmGrads,
        timer: &mut PhaseTimer,
    ) -> f64 {
        let (t_len, b) = (win.t, win.b);
        let cfg = &self.cfg;
        let (h, v, l) = (cfg.hidden, cfg.vocab, cfg.layers);
        assert_eq!(plan.steps.len(), t_len, "mask plan length mismatch");
        assert_eq!(state.batch, b);
        grads.zero();

        // ---------- forward ----------
        let mut caches: Vec<Vec<CellCache>> = Vec::with_capacity(t_len);
        let mut lin_caches = Vec::with_capacity(t_len);
        let mut probs_per_t = Vec::with_capacity(t_len);
        let mut emb_rows: Vec<Vec<f32>> = Vec::with_capacity(t_len);
        let mut loss_sum = 0.0f64;

        let mut hs = state.h.clone();
        let mut cs = state.c.clone();

        for ti in 0..t_len {
            let ids = &win.x[ti * b..(ti + 1) * b];
            let mut inp = vec![0.0f32; b * h];
            timer.time(Phase::Other, || self.emb.fwd(ids, &mut inp));
            emb_rows.push(inp.clone());

            let masks = &plan.steps[ti];
            let mut layer_caches = Vec::with_capacity(l);
            for li in 0..l {
                let (h_new, c_new, cache) = cell_fwd(
                    &self.lstm[li], &inp, &hs[li], &cs[li],
                    &masks.mx[li], &masks.mh[li], b, timer,
                );
                hs[li] = h_new.clone();
                cs[li] = c_new;
                inp = h_new;
                layer_caches.push(cache);
            }
            caches.push(layer_caches);

            // Output dropout + projection + CE.
            let mut logits = vec![0.0f32; b * v];
            let lc = self.proj.fwd(&inp, &masks.mx[l], b, timer, &mut logits);
            lin_caches.push(lc);
            let targets = &win.y[ti * b..(ti + 1) * b];
            let (nll, probs) = timer.time(Phase::Other, || ce_fwd(&logits, targets, b, v));
            loss_sum += nll;
            probs_per_t.push(probs);
        }

        // Detached carry to the next window.
        state.h = hs;
        state.c = cs;

        // ---------- backward ----------
        let inv = 1.0 / (t_len * b) as f32;
        let mut dh_next: Vec<Vec<f32>> = (0..l).map(|_| vec![0.0f32; b * h]).collect();
        let mut dc_next: Vec<Vec<f32>> = (0..l).map(|_| vec![0.0f32; b * h]).collect();

        for ti in (0..t_len).rev() {
            let targets = &win.y[ti * b..(ti + 1) * b];
            let dlogits = timer.time(Phase::Other, || {
                ce_bwd(&probs_per_t[ti], targets, b, v, inv)
            });
            let dtop = self.proj.bwd(&lin_caches[ti], &dlogits, b, &mut grads.proj, timer);

            // Gradient into the top layer's h at this step: projection path
            // plus recurrent path from step t+1.
            let mut dh = dtop;
            for (dhv, nv) in dh.iter_mut().zip(&dh_next[l - 1]) {
                *dhv += nv;
            }

            let mut dx_below: Option<Vec<f32>> = None;
            for li in (0..l).rev() {
                if li < l - 1 {
                    // Non-top layers: gradient = dx from the layer above
                    // plus the recurrent gradient from t+1.
                    dh = dx_below.take().unwrap();
                    for (dhv, nv) in dh.iter_mut().zip(&dh_next[li]) {
                        *dhv += nv;
                    }
                }
                let (dx, dh_prev, dc_prev) = cell_bwd(
                    &self.lstm[li], &caches[ti][li], &dh, &dc_next[li], b,
                    &mut grads.lstm[li], timer,
                );
                dh_next[li] = dh_prev;
                dc_next[li] = dc_prev;
                dx_below = Some(dx);
            }

            // Embedding gradient.
            let ids = &win.x[ti * b..(ti + 1) * b];
            let demb_rows = dx_below.unwrap();
            timer.time(Phase::Other, || {
                self.emb.bwd(ids, &demb_rows, &mut grads.demb)
            });
        }

        loss_sum / (t_len * b) as f64
    }

    /// Evaluation: mean per-token NLL over a window with dropout disabled
    /// (all-ones masks), carrying state like the training path.
    pub fn eval_window(&self, win: &LmWindow, state: &mut LmState) -> f64 {
        let (t_len, b) = (win.t, win.b);
        let (h, v, l) = (self.cfg.hidden, self.cfg.vocab, self.cfg.layers);
        let ones_x = Mask::Ones { h };
        let mut timer = PhaseTimer::new();
        let mut loss_sum = 0.0f64;
        for ti in 0..t_len {
            let ids = &win.x[ti * b..(ti + 1) * b];
            let mut inp = vec![0.0f32; b * h];
            self.emb.fwd(ids, &mut inp);
            for li in 0..l {
                let (h_new, c_new, _) = cell_fwd(
                    &self.lstm[li], &inp, &state.h[li], &state.c[li],
                    &ones_x, &ones_x, b, &mut timer,
                );
                state.h[li] = h_new.clone();
                state.c[li] = c_new;
                inp = h_new;
            }
            let mut logits = vec![0.0f32; b * v];
            self.proj.fwd(&inp, &ones_x, b, &mut timer, &mut logits);
            let targets = &win.y[ti * b..(ti + 1) * b];
            loss_sum += ce_fwd(&logits, targets, b, v).0;
        }
        loss_sum / (t_len * b) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::batcher::LmBatcher;
    use crate::dropout::plan::{DropoutConfig, MaskPlanner};

    fn tiny() -> (LmModel, XorShift64) {
        let mut rng = XorShift64::new(1);
        let cfg = LmModelConfig { vocab: 30, hidden: 12, layers: 2, init_scale: 0.1 };
        (LmModel::init(cfg, &mut rng), rng)
    }

    #[test]
    fn initial_loss_near_ln_v() {
        let (m, mut rng) = tiny();
        let stream: Vec<u32> = (0..600).map(|_| rng.below(30) as u32).collect();
        let mut batcher = LmBatcher::new(&stream, 4, 6);
        let win = batcher.next_window().unwrap();
        let mut planner = MaskPlanner::new(DropoutConfig::none(), 3);
        let plan = planner.plan(6, 4, 12, 2);
        let mut state = LmState::zeros(&m.cfg, 4);
        let mut grads = LmGrads::zeros(&m);
        let mut timer = PhaseTimer::new();
        let loss = m.train_window(&win, &plan, &mut state, &mut grads, &mut timer);
        assert!((loss - (30f64).ln()).abs() < 0.4, "loss={loss}");
        assert!(timer.fp > std::time::Duration::ZERO);
        assert!(timer.bp > std::time::Duration::ZERO);
        assert!(timer.wg > std::time::Duration::ZERO);
    }

    #[test]
    fn sgd_on_repetitive_stream_learns() {
        // A trivially predictable stream: loss must drop fast under SGD.
        let (mut m, _) = tiny();
        let stream: Vec<u32> = (0..2000).map(|i| (i % 7) as u32).collect();
        let mut batcher = LmBatcher::new(&stream, 4, 8);
        let mut planner = MaskPlanner::new(DropoutConfig::nr_rh_st(0.2, 0.2), 5);
        let mut state = LmState::zeros(&m.cfg, 4);
        let mut grads = LmGrads::zeros(&m);
        let mut timer = PhaseTimer::new();

        let mut first = None;
        let mut last = 0.0;
        for _ in 0..100 {
            let win = match batcher.next_window() {
                Some(w) => w,
                None => {
                    batcher.reset();
                    state.reset();
                    batcher.next_window().unwrap()
                }
            };
            let plan = planner.plan(8, 4, 12, 2);
            let loss = m.train_window(&win, &plan, &mut state, &mut grads, &mut timer);
            if first.is_none() {
                first = Some(loss);
            }
            last = loss;
            // SGD step (lr=1.0, matching Zaremba's scale for tiny nets).
            for (p, g) in m.buffers_mut().into_iter().zip(grads.buffers_mut()) {
                for (pv, gv) in p.iter_mut().zip(g.iter()) {
                    *pv -= 0.5 * gv;
                }
            }
        }
        let first = first.unwrap();
        assert!(last < first * 0.6, "loss did not drop: {first} -> {last}");
    }

    #[test]
    fn grads_finite_difference_spot_check() {
        let (m, mut rng) = tiny();
        let stream: Vec<u32> = (0..400).map(|_| rng.below(30) as u32).collect();
        let mut batcher = LmBatcher::new(&stream, 2, 4);
        let win = batcher.next_window().unwrap();
        let mut planner =
            MaskPlanner::new(DropoutConfig::nr_rh_st(0.3, 0.3), 11);
        let plan = planner.plan(4, 2, 12, 2);

        let loss_of = |m: &LmModel| {
            let mut st = LmState::zeros(&m.cfg, 2);
            let mut g = LmGrads::zeros(m);
            let mut t = PhaseTimer::new();
            m.train_window(&win, &plan, &mut st, &mut g, &mut t)
        };

        let mut grads = LmGrads::zeros(&m);
        {
            let mut st = LmState::zeros(&m.cfg, 2);
            let mut t = PhaseTimer::new();
            m.train_window(&win, &plan, &mut st, &mut grads, &mut t);
        }

        let eps = 1e-2f32;
        // Check one coordinate in each of: emb, layer0 U, proj W.
        let checks: Vec<(usize, usize)> = vec![(0, 5), (2, 17), (7, 3)];
        for (buf_idx, coord) in checks {
            let analytic = {
                let bufs = grads.buffers_mut();
                bufs[buf_idx][coord]
            };
            let mut mp = m.clone();
            mp.buffers_mut()[buf_idx][coord] += eps;
            let mut mm = m.clone();
            mm.buffers_mut()[buf_idx][coord] -= eps;
            let num = ((loss_of(&mp) - loss_of(&mm)) / (2.0 * eps as f64)) as f32;
            assert!((analytic - num).abs() < 3e-2 * (1.0 + num.abs()),
                    "buffer {buf_idx} coord {coord}: {analytic} vs {num}");
        }
    }

    #[test]
    fn eval_matches_train_with_no_dropout() {
        let (m, mut rng) = tiny();
        let stream: Vec<u32> = (0..500).map(|_| rng.below(30) as u32).collect();
        let mut b1 = LmBatcher::new(&stream, 4, 6);
        let mut b2 = LmBatcher::new(&stream, 4, 6);
        let win1 = b1.next_window().unwrap();
        let win2 = b2.next_window().unwrap();
        let mut planner = MaskPlanner::new(DropoutConfig::none(), 3);
        let plan = planner.plan(6, 4, 12, 2);
        let mut s1 = LmState::zeros(&m.cfg, 4);
        let mut s2 = LmState::zeros(&m.cfg, 4);
        let mut g = LmGrads::zeros(&m);
        let mut t = PhaseTimer::new();
        let train_loss = m.train_window(&win1, &plan, &mut s1, &mut g, &mut t);
        let eval_loss = m.eval_window(&win2, &mut s2);
        assert!((train_loss - eval_loss).abs() < 1e-6);
    }
}
