//! The Zaremba-style LSTM language model (paper §4.1) on the native
//! engine: embedding → L LSTM layers with structured dropout → output
//! dropout → projection → cross-entropy, with exact BPTT through a
//! `[T, B]` window and hidden state carried across windows.
//!
//! The sequence loop runs on the unified [`crate::rnn`] runtime: one
//! [`StackedLstm`] drives all layers over a preallocated [`LmWorkspace`],
//! so the steady-state training window performs no heap allocation (see
//! `tests/alloc_steady_state.rs`). Phase attribution is centralized via
//! [`PhaseTimer::window`]: FP/BP/WG are charged by the runtime's GEMM and
//! gate kernels, and embedding/softmax/bookkeeping land in `Other` as the
//! wall-clock remainder.

use crate::data::batcher::LmWindow;
use crate::dropout::mask::Mask;
use crate::dropout::plan::MaskPlan;
use crate::dropout::rng::XorShift64;
use crate::gemm::sparse::SparseScratch;
use crate::model::embedding::Embedding;
use crate::model::linear::{Linear, LinearGrads};
use crate::model::lstm::{LstmGrads, LstmParams};
use crate::model::softmax::{ce_bwd_into, ce_fwd_into};
use crate::rnn::tape::size_buf;
use crate::rnn::{Direction, StackedLstm, StepBufs, UnitMasks, Workspace};
use crate::train::timing::PhaseTimer;

/// Static LM configuration (embedding size = hidden size, as in the paper).
#[derive(Debug, Clone, Copy)]
pub struct LmModelConfig {
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub init_scale: f32,
}

/// The model: parameters of all layers.
#[derive(Debug, Clone)]
pub struct LmModel {
    pub cfg: LmModelConfig,
    pub emb: Embedding,
    pub lstm: Vec<LstmParams>,
    pub proj: Linear,
}

/// Gradients matching [`LmModel`].
#[derive(Debug, Clone)]
pub struct LmGrads {
    pub demb: Vec<f32>,
    pub lstm: Vec<LstmGrads>,
    pub proj: LinearGrads,
}

impl LmGrads {
    pub fn zeros(m: &LmModel) -> LmGrads {
        LmGrads {
            demb: vec![0.0; m.emb.w.len()],
            lstm: m.lstm.iter().map(LstmGrads::zeros).collect(),
            proj: LinearGrads::zeros(&m.proj),
        }
    }

    pub fn zero(&mut self) {
        self.demb.fill(0.0);
        for g in &mut self.lstm {
            g.zero();
        }
        self.proj.zero();
    }

    /// Flat view over all gradient buffers (for clipping / updates).
    pub fn buffers_mut(&mut self) -> Vec<&mut [f32]> {
        let mut v: Vec<&mut [f32]> = vec![&mut self.demb];
        for g in &mut self.lstm {
            v.push(&mut g.dw);
            v.push(&mut g.du);
            v.push(&mut g.db);
        }
        v.push(&mut self.proj.dw);
        v.push(&mut self.proj.db);
        v
    }
}

/// Recurrent state carried across BPTT windows (truncated BPTT: detached).
#[derive(Debug, Clone)]
pub struct LmState {
    pub h: Vec<Vec<f32>>,
    pub c: Vec<Vec<f32>>,
    pub batch: usize,
}

impl LmState {
    pub fn zeros(cfg: &LmModelConfig, batch: usize) -> LmState {
        LmState {
            h: (0..cfg.layers).map(|_| vec![0.0; batch * cfg.hidden]).collect(),
            c: (0..cfg.layers).map(|_| vec![0.0; batch * cfg.hidden]).collect(),
            batch,
        }
    }

    pub fn reset(&mut self) {
        for b in self.h.iter_mut().chain(self.c.iter_mut()) {
            b.fill(0.0);
        }
    }
}

/// Preallocated working memory for LM training/evaluation: the sequence
/// runtime's workspace plus the head-side step buffers (embedding inputs,
/// per-step softmax caches, masked projection inputs, head gradients).
/// Create once per run and reuse across windows — after warm-up, a
/// steady-state `train_window` call allocates nothing.
#[derive(Debug, Default)]
pub struct LmWorkspace {
    seq: Workspace,
    xs: StepBufs,
    dtop: StepBufs,
    probs: StepBufs,
    head_xd: StepBufs,
    logits: Vec<f32>,
    dlogits: Vec<f32>,
    scratch: SparseScratch,
    unit: UnitMasks,
}

impl LmWorkspace {
    pub fn new() -> LmWorkspace {
        LmWorkspace::default()
    }
}

impl LmModel {
    pub fn init(cfg: LmModelConfig, rng: &mut XorShift64) -> LmModel {
        let s = cfg.init_scale;
        let emb = Embedding::init(cfg.vocab, cfg.hidden, s, rng);
        let lstm = (0..cfg.layers)
            .map(|_| LstmParams::init(cfg.hidden, cfg.hidden, s, rng))
            .collect();
        let proj = Linear::init(cfg.hidden, cfg.vocab, s, rng);
        LmModel { cfg, emb, lstm, proj }
    }

    pub fn param_count(&self) -> usize {
        self.emb.w.len()
            + self.lstm.iter().map(LstmParams::numel).sum::<usize>()
            + self.proj.w.len()
            + self.proj.b.len()
    }

    /// Flat view over all parameter buffers, ordered to match
    /// [`LmGrads::buffers_mut`] and the XLA manifest parameter order.
    pub fn buffers_mut(&mut self) -> Vec<&mut [f32]> {
        let mut v: Vec<&mut [f32]> = vec![&mut self.emb.w];
        for p in &mut self.lstm {
            v.push(&mut p.w);
            v.push(&mut p.u);
            v.push(&mut p.b);
        }
        v.push(&mut self.proj.w);
        v.push(&mut self.proj.b);
        v
    }

    pub fn buffers(&self) -> Vec<&[f32]> {
        let mut v: Vec<&[f32]> = vec![&self.emb.w];
        for p in &self.lstm {
            v.push(&p.w);
            v.push(&p.u);
            v.push(&p.b);
        }
        v.push(&self.proj.w);
        v.push(&self.proj.b);
        v
    }

    /// One training window: forward + backward with exact BPTT through the
    /// `rnn::` runtime, returning the mean per-token NLL. Gradients
    /// accumulate into `grads` (zeroed here); recurrent state in `state`
    /// is updated (detached) for the next window. `ws` persists across
    /// windows — its buffers are sized on first use and reused after.
    pub fn train_window(
        &self,
        win: &LmWindow,
        plan: &MaskPlan,
        state: &mut LmState,
        grads: &mut LmGrads,
        ws: &mut LmWorkspace,
        timer: &mut PhaseTimer,
    ) -> f64 {
        timer.window(|t| self.train_window_inner(win, plan, state, grads, ws, t))
    }

    fn train_window_inner(
        &self,
        win: &LmWindow,
        plan: &MaskPlan,
        state: &mut LmState,
        grads: &mut LmGrads,
        ws: &mut LmWorkspace,
        timer: &mut PhaseTimer,
    ) -> f64 {
        let (t_len, b) = (win.t, win.b);
        let cfg = &self.cfg;
        let (h, v, l) = (cfg.hidden, cfg.vocab, cfg.layers);
        assert_eq!(plan.steps.len(), t_len, "mask plan length mismatch");
        assert_eq!(state.batch, b);
        grads.zero();

        // ---------- forward ----------
        ws.xs.ensure(t_len, b * h);
        for ti in 0..t_len {
            let ids = &win.x[ti * b..(ti + 1) * b];
            self.emb.fwd(ids, ws.xs.buf_mut(ti));
        }
        let rt = StackedLstm::new(&self.lstm);
        rt.forward(&mut ws.seq, &ws.xs, plan, t_len, b,
                   Some((state.h.as_slice(), state.c.as_slice())), Direction::Forward, timer);

        // Detached carry to the next window.
        for li in 0..l {
            state.h[li].copy_from_slice(ws.seq.tape.h_out(t_len - 1, li));
            state.c[li].copy_from_slice(ws.seq.tape.c_out(t_len - 1, li));
        }

        // Output dropout + projection + CE per step.
        ws.probs.ensure(t_len, b * v);
        ws.head_xd.ensure(t_len, b * h);
        ws.dtop.ensure(t_len, b * h);
        size_buf(&mut ws.logits, b * v);
        size_buf(&mut ws.dlogits, b * v);
        let mut loss_sum = 0.0f64;
        for ti in 0..t_len {
            let om = &plan.steps[ti].mx[l];
            self.proj.fwd_ws(ws.seq.tape.h_top(ti), om, b, timer,
                             ws.head_xd.vec_mut(ti), &mut ws.logits, &mut ws.scratch);
            let targets = &win.y[ti * b..(ti + 1) * b];
            loss_sum += ce_fwd_into(&ws.logits, targets, b, v, ws.probs.buf_mut(ti));
        }

        // ---------- backward ----------
        // Head first (reverse step order, matching the BPTT loop), filling
        // the per-step gradient into the top layer's h.
        let inv = 1.0 / (t_len * b) as f32;
        for ti in (0..t_len).rev() {
            let targets = &win.y[ti * b..(ti + 1) * b];
            ce_bwd_into(ws.probs.buf(ti), targets, b, v, inv, &mut ws.dlogits);
            let om = &plan.steps[ti].mx[l];
            self.proj.bwd_ws(ws.head_xd.buf(ti), om, &ws.dlogits, b, &mut grads.proj,
                             timer, ws.dtop.buf_mut(ti), &mut ws.scratch);
        }

        // BPTT through the stack; the sink scatters embedding gradients.
        rt.backward(&mut ws.seq, &ws.dtop, plan, t_len, b, None, &mut grads.lstm,
                    Direction::Forward, timer, |ti, dx| {
                        let ids = &win.x[ti * b..(ti + 1) * b];
                        self.emb.bwd(ids, dx, &mut grads.demb);
                    });

        loss_sum / (t_len * b) as f64
    }

    /// Evaluation: mean per-token NLL over a window with dropout disabled,
    /// carrying state like the training path. Identity masks are hoisted
    /// (built once per model shape, not per timestep).
    pub fn eval_window(&self, win: &LmWindow, state: &mut LmState, ws: &mut LmWorkspace) -> f64 {
        let (t_len, b) = (win.t, win.b);
        let (h, v, l) = (self.cfg.hidden, self.cfg.vocab, self.cfg.layers);
        assert_eq!(state.batch, b);
        let mut timer = PhaseTimer::new();

        if !ws.unit.matches(&self.lstm) {
            ws.unit = UnitMasks::for_layers(&self.lstm);
        }
        ws.xs.ensure(t_len, b * h);
        for ti in 0..t_len {
            let ids = &win.x[ti * b..(ti + 1) * b];
            self.emb.fwd(ids, ws.xs.buf_mut(ti));
        }
        let rt = StackedLstm::new(&self.lstm);
        rt.forward(&mut ws.seq, &ws.xs, &ws.unit, t_len, b,
                   Some((state.h.as_slice(), state.c.as_slice())), Direction::Forward, &mut timer);
        for li in 0..l {
            state.h[li].copy_from_slice(ws.seq.tape.h_out(t_len - 1, li));
            state.c[li].copy_from_slice(ws.seq.tape.c_out(t_len - 1, li));
        }

        let ones = Mask::Ones { h };
        ws.probs.ensure(1, b * v);
        ws.head_xd.ensure(1, b * h);
        size_buf(&mut ws.logits, b * v);
        let mut loss_sum = 0.0f64;
        for ti in 0..t_len {
            self.proj.fwd_ws(ws.seq.tape.h_top(ti), &ones, b, &mut timer,
                             ws.head_xd.vec_mut(0), &mut ws.logits, &mut ws.scratch);
            let targets = &win.y[ti * b..(ti + 1) * b];
            loss_sum += ce_fwd_into(&ws.logits, targets, b, v, ws.probs.buf_mut(0));
        }
        loss_sum / (t_len * b) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::batcher::LmBatcher;
    use crate::dropout::plan::{DropoutConfig, MaskPlanner};

    fn tiny() -> (LmModel, XorShift64) {
        let mut rng = XorShift64::new(1);
        let cfg = LmModelConfig { vocab: 30, hidden: 12, layers: 2, init_scale: 0.1 };
        (LmModel::init(cfg, &mut rng), rng)
    }

    #[test]
    fn initial_loss_near_ln_v() {
        let (m, mut rng) = tiny();
        let stream: Vec<u32> = (0..600).map(|_| rng.below(30) as u32).collect();
        let mut batcher = LmBatcher::new(&stream, 4, 6);
        let win = batcher.next_window().unwrap();
        let mut planner = MaskPlanner::new(DropoutConfig::none(), 3);
        let plan = planner.plan(6, 4, 12, 2);
        let mut state = LmState::zeros(&m.cfg, 4);
        let mut grads = LmGrads::zeros(&m);
        let mut ws = LmWorkspace::new();
        let mut timer = PhaseTimer::new();
        let wall0 = std::time::Instant::now();
        let loss = m.train_window(&win, &plan, &mut state, &mut grads, &mut ws, &mut timer);
        let wall = wall0.elapsed();
        assert!((loss - (30f64).ln()).abs() < 0.4, "loss={loss}");
        assert!(timer.fp > std::time::Duration::ZERO);
        assert!(timer.bp > std::time::Duration::ZERO);
        assert!(timer.wg > std::time::Duration::ZERO);
        // Centralized attribution: the four phases account for the whole
        // window — nothing double-counted (sum bounded by the wall clock
        // we measured around the call) and nothing dropped (the
        // embedding/softmax remainder lands in Other, not nowhere).
        assert!(timer.total() <= wall,
                "phases {:?} exceed window wall time {wall:?}", timer.total());
        assert!(timer.other > std::time::Duration::ZERO,
                "embedding/softmax time must land in Other");
    }

    #[test]
    fn sgd_on_repetitive_stream_learns() {
        // A trivially predictable stream: loss must drop fast under SGD.
        let (mut m, _) = tiny();
        let stream: Vec<u32> = (0..2000).map(|i| (i % 7) as u32).collect();
        let mut batcher = LmBatcher::new(&stream, 4, 8);
        let mut planner = MaskPlanner::new(DropoutConfig::nr_rh_st(0.2, 0.2), 5);
        let mut state = LmState::zeros(&m.cfg, 4);
        let mut grads = LmGrads::zeros(&m);
        let mut ws = LmWorkspace::new();
        let mut timer = PhaseTimer::new();

        let mut first = None;
        let mut last = 0.0;
        for _ in 0..100 {
            let win = match batcher.next_window() {
                Some(w) => w,
                None => {
                    batcher.reset();
                    state.reset();
                    batcher.next_window().unwrap()
                }
            };
            let plan = planner.plan(8, 4, 12, 2);
            let loss = m.train_window(&win, &plan, &mut state, &mut grads, &mut ws, &mut timer);
            if first.is_none() {
                first = Some(loss);
            }
            last = loss;
            // SGD step (lr=1.0, matching Zaremba's scale for tiny nets).
            for (p, g) in m.buffers_mut().into_iter().zip(grads.buffers_mut()) {
                for (pv, gv) in p.iter_mut().zip(g.iter()) {
                    *pv -= 0.5 * gv;
                }
            }
        }
        let first = first.unwrap();
        assert!(last < first * 0.6, "loss did not drop: {first} -> {last}");
    }

    #[test]
    fn grads_finite_difference_spot_check() {
        let (m, mut rng) = tiny();
        let stream: Vec<u32> = (0..400).map(|_| rng.below(30) as u32).collect();
        let mut batcher = LmBatcher::new(&stream, 2, 4);
        let win = batcher.next_window().unwrap();
        let mut planner =
            MaskPlanner::new(DropoutConfig::nr_rh_st(0.3, 0.3), 11);
        let plan = planner.plan(4, 2, 12, 2);

        let loss_of = |m: &LmModel| {
            let mut st = LmState::zeros(&m.cfg, 2);
            let mut g = LmGrads::zeros(m);
            let mut w = LmWorkspace::new();
            let mut t = PhaseTimer::new();
            m.train_window(&win, &plan, &mut st, &mut g, &mut w, &mut t)
        };

        let mut grads = LmGrads::zeros(&m);
        {
            let mut st = LmState::zeros(&m.cfg, 2);
            let mut w = LmWorkspace::new();
            let mut t = PhaseTimer::new();
            m.train_window(&win, &plan, &mut st, &mut grads, &mut w, &mut t);
        }

        let eps = 1e-2f32;
        // Check one coordinate in each of: emb, layer0 U, proj W.
        let checks: Vec<(usize, usize)> = vec![(0, 5), (2, 17), (7, 3)];
        for (buf_idx, coord) in checks {
            let analytic = {
                let bufs = grads.buffers_mut();
                bufs[buf_idx][coord]
            };
            let mut mp = m.clone();
            mp.buffers_mut()[buf_idx][coord] += eps;
            let mut mm = m.clone();
            mm.buffers_mut()[buf_idx][coord] -= eps;
            let num = ((loss_of(&mp) - loss_of(&mm)) / (2.0 * eps as f64)) as f32;
            assert!((analytic - num).abs() < 3e-2 * (1.0 + num.abs()),
                    "buffer {buf_idx} coord {coord}: {analytic} vs {num}");
        }
    }

    #[test]
    fn eval_matches_train_with_no_dropout() {
        let (m, mut rng) = tiny();
        let stream: Vec<u32> = (0..500).map(|_| rng.below(30) as u32).collect();
        let mut b1 = LmBatcher::new(&stream, 4, 6);
        let mut b2 = LmBatcher::new(&stream, 4, 6);
        let win1 = b1.next_window().unwrap();
        let win2 = b2.next_window().unwrap();
        let mut planner = MaskPlanner::new(DropoutConfig::none(), 3);
        let plan = planner.plan(6, 4, 12, 2);
        let mut s1 = LmState::zeros(&m.cfg, 4);
        let mut s2 = LmState::zeros(&m.cfg, 4);
        let mut g = LmGrads::zeros(&m);
        let mut ws = LmWorkspace::new();
        let mut t = PhaseTimer::new();
        let train_loss = m.train_window(&win1, &plan, &mut s1, &mut g, &mut ws, &mut t);
        let eval_loss = m.eval_window(&win2, &mut s2, &mut ws);
        assert!((train_loss - eval_loss).abs() < 1e-6);
    }

    #[test]
    fn workspace_reuse_is_bitwise_deterministic() {
        // The same window through a fresh workspace and a warm (reused)
        // workspace must produce identical losses and gradients.
        let (m, mut rng) = tiny();
        let stream: Vec<u32> = (0..600).map(|_| rng.below(30) as u32).collect();
        let mut batcher = LmBatcher::new(&stream, 4, 6);
        let win = batcher.next_window().unwrap();
        let mut planner = MaskPlanner::new(DropoutConfig::nr_rh_st(0.25, 0.25), 9);
        let plan = planner.plan(6, 4, 12, 2);

        let run = |ws: &mut LmWorkspace| {
            let mut st = LmState::zeros(&m.cfg, 4);
            let mut g = LmGrads::zeros(&m);
            let mut t = PhaseTimer::new();
            let loss = m.train_window(&win, &plan, &mut st, &mut g, ws, &mut t);
            (loss, g)
        };

        let mut warm = LmWorkspace::new();
        let (_, _) = run(&mut warm);
        let (_, _) = run(&mut warm);
        let (warm_loss, mut warm_grads) = run(&mut warm);
        let mut fresh = LmWorkspace::new();
        let (fresh_loss, mut fresh_grads) = run(&mut fresh);

        assert_eq!(warm_loss.to_bits(), fresh_loss.to_bits(), "loss drifted");
        for (a, b) in warm_grads.buffers_mut().iter().zip(fresh_grads.buffers_mut().iter()) {
            assert_eq!(a, b, "gradient buffer drifted between fresh and warm ws");
        }
    }
}
