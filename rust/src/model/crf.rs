//! Linear-chain CRF decoding head (Lafferty et al., 2001), as used by the
//! paper's §4.3 NER model (Ma & Hovy's BiLSTM-CNNs-CRF). Implements the
//! forward algorithm in log space for the NLL loss, forward–backward for
//! exact gradients (marginal minus empirical counts), and Viterbi decode.

use crate::dropout::rng::XorShift64;

/// CRF parameters over `n` tags: transition scores plus start/end scores.
#[derive(Debug, Clone)]
pub struct Crf {
    pub n: usize,
    /// `[n, n]`: `trans[i*n + j]` scores tag `i` → tag `j`.
    pub trans: Vec<f32>,
    pub start: Vec<f32>,
    pub end: Vec<f32>,
}

/// Gradients for [`Crf`].
#[derive(Debug, Clone)]
pub struct CrfGrads {
    pub dtrans: Vec<f32>,
    pub dstart: Vec<f32>,
    pub dend: Vec<f32>,
}

impl CrfGrads {
    pub fn zeros(c: &Crf) -> CrfGrads {
        CrfGrads {
            dtrans: vec![0.0; c.trans.len()],
            dstart: vec![0.0; c.start.len()],
            dend: vec![0.0; c.end.len()],
        }
    }

    pub fn zero(&mut self) {
        self.dtrans.fill(0.0);
        self.dstart.fill(0.0);
        self.dend.fill(0.0);
    }
}

fn logsumexp(xs: &[f64]) -> f64 {
    let mx = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if mx.is_infinite() {
        return mx;
    }
    mx + xs.iter().map(|x| (x - mx).exp()).sum::<f64>().ln()
}

impl Crf {
    pub fn init(n: usize, scale: f32, rng: &mut XorShift64) -> Crf {
        Crf {
            n,
            trans: (0..n * n).map(|_| rng.uniform(-scale, scale)).collect(),
            start: (0..n).map(|_| rng.uniform(-scale, scale)).collect(),
            end: (0..n).map(|_| rng.uniform(-scale, scale)).collect(),
        }
    }

    /// NLL of `tags` under emissions `e[t*n + i]` for one sequence of
    /// length `t_len`, plus the gradient wrt emissions (returned) and the
    /// CRF parameters (accumulated into `grads`).
    pub fn nll_and_grad(
        &self, e: &[f32], tags: &[u8], t_len: usize, grads: &mut CrfGrads,
    ) -> (f64, Vec<f32>) {
        let n = self.n;
        assert_eq!(e.len(), t_len * n);
        assert_eq!(tags.len(), t_len);
        assert!(t_len > 0);

        // Forward (alpha) and backward (beta) recursions in log space.
        let mut alpha = vec![0.0f64; t_len * n];
        for i in 0..n {
            alpha[i] = self.start[i] as f64 + e[i] as f64;
        }
        let mut buf = vec![0.0f64; n];
        for t in 1..t_len {
            for j in 0..n {
                for (i, bi) in buf.iter_mut().enumerate() {
                    *bi = alpha[(t - 1) * n + i] + self.trans[i * n + j] as f64;
                }
                alpha[t * n + j] = logsumexp(&buf) + e[t * n + j] as f64;
            }
        }
        for (i, bi) in buf.iter_mut().enumerate() {
            *bi = alpha[(t_len - 1) * n + i] + self.end[i] as f64;
        }
        let log_z = logsumexp(&buf);

        let mut beta = vec![0.0f64; t_len * n];
        for i in 0..n {
            beta[(t_len - 1) * n + i] = self.end[i] as f64;
        }
        for t in (0..t_len - 1).rev() {
            for i in 0..n {
                for (j, bj) in buf.iter_mut().enumerate() {
                    *bj = self.trans[i * n + j] as f64
                        + e[(t + 1) * n + j] as f64
                        + beta[(t + 1) * n + j];
                }
                beta[t * n + i] = logsumexp(&buf);
            }
        }

        // Gold path score.
        let mut gold = self.start[tags[0] as usize] as f64 + e[tags[0] as usize] as f64;
        for t in 1..t_len {
            gold += self.trans[tags[t - 1] as usize * n + tags[t] as usize] as f64
                + e[t * n + tags[t] as usize] as f64;
        }
        gold += self.end[tags[t_len - 1] as usize] as f64;
        let nll = log_z - gold;

        // Gradients: marginals minus empirical indicators.
        let mut de = vec![0.0f32; t_len * n];
        for t in 0..t_len {
            for i in 0..n {
                let marg = (alpha[t * n + i] + beta[t * n + i] - log_z).exp();
                de[t * n + i] = marg as f32;
            }
            de[t * n + tags[t] as usize] -= 1.0;
        }
        for i in 0..n {
            grads.dstart[i] += (alpha[i] + beta[i] - log_z).exp() as f32;
        }
        grads.dstart[tags[0] as usize] -= 1.0;
        for i in 0..n {
            // beta[T-1] = end, so alpha+beta-logZ is the marginal at T-1,
            // which is exactly ∂logZ/∂end[i].
            let m = (alpha[(t_len - 1) * n + i] + beta[(t_len - 1) * n + i] - log_z).exp();
            grads.dend[i] += m as f32;
        }
        grads.dend[tags[t_len - 1] as usize] -= 1.0;
        for t in 1..t_len {
            for i in 0..n {
                for j in 0..n {
                    let pair = (alpha[(t - 1) * n + i]
                        + self.trans[i * n + j] as f64
                        + e[t * n + j] as f64
                        + beta[t * n + j]
                        - log_z)
                        .exp();
                    grads.dtrans[i * n + j] += pair as f32;
                }
            }
            grads.dtrans[tags[t - 1] as usize * n + tags[t] as usize] -= 1.0;
        }

        (nll, de)
    }

    /// Viterbi decode: best tag sequence for emissions `e[t*n + i]`.
    pub fn viterbi(&self, e: &[f32], t_len: usize) -> Vec<u8> {
        let n = self.n;
        assert_eq!(e.len(), t_len * n);
        let mut delta = vec![f64::NEG_INFINITY; t_len * n];
        let mut psi = vec![0usize; t_len * n];
        for i in 0..n {
            delta[i] = self.start[i] as f64 + e[i] as f64;
        }
        for t in 1..t_len {
            for j in 0..n {
                let mut best = f64::NEG_INFINITY;
                let mut arg = 0;
                for i in 0..n {
                    let v = delta[(t - 1) * n + i] + self.trans[i * n + j] as f64;
                    if v > best {
                        best = v;
                        arg = i;
                    }
                }
                delta[t * n + j] = best + e[t * n + j] as f64;
                psi[t * n + j] = arg;
            }
        }
        let mut best = f64::NEG_INFINITY;
        let mut cur = 0usize;
        for i in 0..n {
            let v = delta[(t_len - 1) * n + i] + self.end[i] as f64;
            if v > best {
                best = v;
                cur = i;
            }
        }
        let mut path = vec![0u8; t_len];
        path[t_len - 1] = cur as u8;
        for t in (1..t_len).rev() {
            cur = psi[t * n + cur];
            path[t - 1] = cur as u8;
        }
        path
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn nll_is_proper_negative_log_prob() {
        // For any sequence, exp(-nll) must be a probability (< 1) and the
        // sum over all tag sequences must be 1; check on a tiny case by
        // brute-force enumeration.
        let mut rng = XorShift64::new(1);
        let n = 3;
        let t_len = 3;
        let crf = Crf::init(n, 0.5, &mut rng);
        let e = prop::vec_f32(&mut rng, t_len * n, 1.0);

        let mut total = 0.0f64;
        for a in 0..n {
            for b in 0..n {
                for c in 0..n {
                    let tags = [a as u8, b as u8, c as u8];
                    let mut g = CrfGrads::zeros(&crf);
                    let (nll, _) = crf.nll_and_grad(&e, &tags, t_len, &mut g);
                    total += (-nll).exp();
                }
            }
        }
        assert!((total - 1.0).abs() < 1e-6, "total prob = {total}");
    }

    #[test]
    fn gradients_match_finite_differences() {
        let mut rng = XorShift64::new(2);
        let n = 4;
        let t_len = 5;
        let crf = Crf::init(n, 0.5, &mut rng);
        let e = prop::vec_f32(&mut rng, t_len * n, 1.0);
        let tags = vec![0u8, 2, 1, 3, 2];

        let mut grads = CrfGrads::zeros(&crf);
        let (_, de) = crf.nll_and_grad(&e, &tags, t_len, &mut grads);

        let eps = 1e-3f32;
        let nll_of = |crf: &Crf, e: &[f32]| {
            let mut g = CrfGrads::zeros(crf);
            crf.nll_and_grad(e, &tags, t_len, &mut g).0
        };
        for idx in 0..t_len * n {
            let mut ep = e.clone();
            ep[idx] += eps;
            let mut em = e.clone();
            em[idx] -= eps;
            let num = ((nll_of(&crf, &ep) - nll_of(&crf, &em)) / (2.0 * eps as f64)) as f32;
            assert!((de[idx] - num).abs() < 1e-2 * (1.0 + num.abs()),
                    "de[{idx}] {} vs {num}", de[idx]);
        }
        for idx in 0..n * n {
            let mut cp = crf.clone();
            cp.trans[idx] += eps;
            let mut cm = crf.clone();
            cm.trans[idx] -= eps;
            let num = ((nll_of(&cp, &e) - nll_of(&cm, &e)) / (2.0 * eps as f64)) as f32;
            assert!((grads.dtrans[idx] - num).abs() < 1e-2 * (1.0 + num.abs()),
                    "dtrans[{idx}] {} vs {num}", grads.dtrans[idx]);
        }
        for idx in 0..n {
            let mut cp = crf.clone();
            cp.start[idx] += eps;
            let mut cm = crf.clone();
            cm.start[idx] -= eps;
            let num = ((nll_of(&cp, &e) - nll_of(&cm, &e)) / (2.0 * eps as f64)) as f32;
            assert!((grads.dstart[idx] - num).abs() < 1e-2 * (1.0 + num.abs()),
                    "dstart[{idx}] {} vs {num}", grads.dstart[idx]);

            let mut cp = crf.clone();
            cp.end[idx] += eps;
            let mut cm = crf.clone();
            cm.end[idx] -= eps;
            let num = ((nll_of(&cp, &e) - nll_of(&cm, &e)) / (2.0 * eps as f64)) as f32;
            assert!((grads.dend[idx] - num).abs() < 1e-2 * (1.0 + num.abs()),
                    "dend[{idx}] {} vs {num}", grads.dend[idx]);
        }
    }

    #[test]
    fn viterbi_finds_argmax_sequence() {
        // Brute-force cross-check on a small case.
        let mut rng = XorShift64::new(3);
        let n = 3;
        let t_len = 4;
        let crf = Crf::init(n, 0.8, &mut rng);
        let e = prop::vec_f32(&mut rng, t_len * n, 1.5);

        let score = |tags: &[u8]| {
            let mut s = crf.start[tags[0] as usize] as f64 + e[tags[0] as usize] as f64;
            for t in 1..t_len {
                s += crf.trans[tags[t - 1] as usize * n + tags[t] as usize] as f64
                    + e[t * n + tags[t] as usize] as f64;
            }
            s + crf.end[tags[t_len - 1] as usize] as f64
        };

        let mut best_score = f64::NEG_INFINITY;
        let mut best = vec![0u8; t_len];
        for a in 0..n as u8 {
            for b in 0..n as u8 {
                for c in 0..n as u8 {
                    for d in 0..n as u8 {
                        let tags = [a, b, c, d];
                        let s = score(&tags);
                        if s > best_score {
                            best_score = s;
                            best = tags.to_vec();
                        }
                    }
                }
            }
        }
        assert_eq!(crf.viterbi(&e, t_len), best);
    }

    #[test]
    fn strong_emissions_dominate_decode() {
        let crf = Crf {
            n: 2,
            trans: vec![0.0; 4],
            start: vec![0.0; 2],
            end: vec![0.0; 2],
        };
        let e = vec![10.0, -10.0, -10.0, 10.0, 10.0, -10.0];
        assert_eq!(crf.viterbi(&e, 3), vec![0, 1, 0]);
    }

    #[test]
    fn length_one_sequence() {
        let mut rng = XorShift64::new(4);
        let crf = Crf::init(3, 0.5, &mut rng);
        let e = vec![0.5f32, -0.2, 1.0];
        let mut g = CrfGrads::zeros(&crf);
        let (nll, de) = crf.nll_and_grad(&e, &[2], 1, &mut g);
        assert!(nll.is_finite() && nll >= 0.0 || nll > -1e-9);
        assert_eq!(de.len(), 3);
        assert_eq!(crf.viterbi(&e, 1).len(), 1);
    }
}
