//! Native training engine: layers with structured-sparsity-aware
//! forward/backward, and the three task models of the paper's evaluation
//! (LSTM LM, attention NMT, BiLSTM-CRF NER). All three drive their
//! sequence loops through the unified [`crate::rnn`] runtime (one BPTT
//! tape + preallocated workspaces), re-exported here for convenience.

pub mod embedding;
pub mod linear;
pub mod lm;
pub mod lstm;
pub mod softmax;

pub mod attention;
pub mod bilstm;
pub mod crf;
pub mod encoder_decoder;

pub use lm::{LmGrads, LmModel, LmModelConfig, LmState, LmWorkspace};
pub use lstm::{cell_bwd, cell_fwd, CellCache, LstmGrads, LstmParams};

// The sequence runtime the models are built on — re-exported so external
// callers that previously reached for the per-model loop types keep a
// single import root.
pub use crate::rnn::{
    DirMasks, Direction, MaskSource, SeqTape, StackedLstm, StepBufs, UnitMasks, Workspace,
};
