//! Native training engine: layers with structured-sparsity-aware
//! forward/backward, and the three task models of the paper's evaluation
//! (LSTM LM, attention NMT, BiLSTM-CRF NER).

pub mod embedding;
pub mod linear;
pub mod lm;
pub mod lstm;
pub mod softmax;

pub mod attention;
pub mod bilstm;
pub mod crf;
pub mod encoder_decoder;

pub use lm::{LmGrads, LmModel, LmModelConfig, LmState};
pub use lstm::{cell_bwd, cell_fwd, CellCache, LstmGrads, LstmParams};
