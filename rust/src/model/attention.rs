//! Luong global attention (dot score) with hand-derived backward — the
//! attention used by the paper's §4.2 NMT model (Luong et al., 2015).
//!
//! Forward, per decoder step:
//!   score[b,s] = h_dec[b]·He[b,s]          (dot score)
//!   a = softmax(score) over valid source positions
//!   ctx[b]     = Σ_s a[b,s] · He[b,s]
//!   ĥ          = tanh([ctx; h_dec] · Wc + bc)
//!
//! The `[2h, h]` combiner GEMM is part of the decoder's FP/BP/WG budget
//! and is charged to the caller's `PhaseTimer`.

use crate::dropout::rng::XorShift64;
use crate::gemm::{matmul, matmul_a_bt, matmul_at_b};
use crate::train::timing::{Phase, PhaseTimer};

/// Attention combiner parameters.
#[derive(Debug, Clone)]
pub struct Attention {
    pub h: usize,
    /// `[2h, h]` combiner weight over `[ctx; h_dec]`.
    pub wc: Vec<f32>,
    pub bc: Vec<f32>,
}

/// Gradients for [`Attention`].
#[derive(Debug, Clone)]
pub struct AttentionGrads {
    pub dwc: Vec<f32>,
    pub dbc: Vec<f32>,
}

impl AttentionGrads {
    pub fn zeros(a: &Attention) -> AttentionGrads {
        AttentionGrads { dwc: vec![0.0; a.wc.len()], dbc: vec![0.0; a.bc.len()] }
    }

    pub fn zero(&mut self) {
        self.dwc.fill(0.0);
        self.dbc.fill(0.0);
    }
}

/// Forward residuals for one step.
#[derive(Debug, Clone)]
pub struct AttnCache {
    /// Attention weights `[b, s]`.
    pub a: Vec<f32>,
    /// Concatenated `[ctx; h_dec]`, `[b, 2h]`.
    pub cat: Vec<f32>,
    /// Output `ĥ` pre-saved for the tanh pullback, `[b, h]`.
    pub hhat: Vec<f32>,
    pub s: usize,
}

impl Attention {
    pub fn init(h: usize, scale: f32, rng: &mut XorShift64) -> Attention {
        Attention {
            h,
            wc: (0..2 * h * h).map(|_| rng.uniform(-scale, scale)).collect(),
            bc: vec![0.0; h],
        }
    }

    /// One attention step. `he: [b, s, h]` encoder outputs (row-major),
    /// `src_len[b]` valid lengths; positions `>= src_len[b]` are masked.
    /// Writes `ĥ` into `out [b, h]`.
    pub fn fwd(
        &self, h_dec: &[f32], he: &[f32], src_len: &[usize],
        b: usize, s: usize, timer: &mut PhaseTimer, out: &mut [f32],
    ) -> AttnCache {
        let h = self.h;
        assert_eq!(h_dec.len(), b * h);
        assert_eq!(he.len(), b * s * h);
        assert_eq!(out.len(), b * h);

        let mut a = vec![0.0f32; b * s];
        let mut cat = vec![0.0f32; b * 2 * h];
        timer.time(Phase::Fp, || {
            for r in 0..b {
                let hrow = &h_dec[r * h..(r + 1) * h];
                let valid = src_len[r].min(s).max(1);
                // dot scores + stable softmax over valid positions
                let mut mx = f32::NEG_INFINITY;
                for t in 0..valid {
                    let erow = &he[(r * s + t) * h..(r * s + t + 1) * h];
                    let mut sc = 0.0f32;
                    for (x, y) in hrow.iter().zip(erow) {
                        sc += x * y;
                    }
                    a[r * s + t] = sc;
                    mx = mx.max(sc);
                }
                let mut z = 0.0f32;
                for t in 0..valid {
                    let e = (a[r * s + t] - mx).exp();
                    a[r * s + t] = e;
                    z += e;
                }
                for t in 0..valid {
                    a[r * s + t] /= z;
                }
                // context
                let ctx = &mut cat[r * 2 * h..r * 2 * h + h];
                for t in 0..valid {
                    let w = a[r * s + t];
                    let erow = &he[(r * s + t) * h..(r * s + t + 1) * h];
                    for (c, &e) in ctx.iter_mut().zip(erow) {
                        *c += w * e;
                    }
                }
                cat[r * 2 * h + h..(r + 1) * 2 * h].copy_from_slice(hrow);
            }
            // ĥ = tanh(cat @ Wc + bc)
            matmul(&cat, &self.wc, out, b, 2 * h, h);
            for r in 0..b {
                for j in 0..h {
                    out[r * h + j] = (out[r * h + j] + self.bc[j]).tanh();
                }
            }
        });
        AttnCache { a, cat, hhat: out.to_vec(), s }
    }

    /// Backward. `dhhat: [b, h]` is the gradient on `ĥ`. Accumulates
    /// `dHe [b, s, h]` (+=) and the combiner grads; returns `dh_dec [b, h]`.
    #[allow(clippy::too_many_arguments)]
    pub fn bwd(
        &self, cache: &AttnCache, he: &[f32], src_len: &[usize],
        dhhat: &[f32], b: usize, grads: &mut AttentionGrads,
        dhe: &mut [f32], timer: &mut PhaseTimer,
    ) -> Vec<f32> {
        let h = self.h;
        let s = cache.s;
        let mut dh_dec = vec![0.0f32; b * h];

        timer.time(Phase::Bp, || {
            // tanh pullback
            let mut dpre = vec![0.0f32; b * h];
            for i in 0..b * h {
                let y = cache.hhat[i];
                dpre[i] = dhhat[i] * (1.0 - y * y);
            }
            // combiner
            let mut dcat = vec![0.0f32; b * 2 * h];
            matmul_a_bt(&dpre, &self.wc, &mut dcat, b, h, 2 * h);
            let mut tmp = vec![0.0f32; 2 * h * h];
            matmul_at_b(&cache.cat, &dpre, &mut tmp, b, 2 * h, h);
            for (d, t) in grads.dwc.iter_mut().zip(&tmp) {
                *d += t;
            }
            for r in 0..b {
                for j in 0..h {
                    grads.dbc[j] += dpre[r * h + j];
                }
            }

            for r in 0..b {
                let valid = src_len[r].min(s).max(1);
                let dctx = &dcat[r * 2 * h..r * 2 * h + h];
                // dh_dec direct path from the concat
                dh_dec[r * h..(r + 1) * h]
                    .copy_from_slice(&dcat[r * 2 * h + h..(r + 1) * 2 * h]);

                // context → attention weights and encoder states
                let mut da = vec![0.0f32; valid];
                for (t, dat) in da.iter_mut().enumerate() {
                    let erow = &he[(r * s + t) * h..(r * s + t + 1) * h];
                    let w = cache.a[r * s + t];
                    let mut acc = 0.0f32;
                    for (dc, &e) in dctx.iter().zip(erow) {
                        acc += dc * e;
                    }
                    *dat = acc;
                    let drow = &mut dhe[(r * s + t) * h..(r * s + t + 1) * h];
                    for (d, &dc) in drow.iter_mut().zip(dctx) {
                        *d += w * dc;
                    }
                }
                // softmax pullback: ds = a ⊙ (da - Σ a·da)
                let dot: f32 = (0..valid).map(|t| cache.a[r * s + t] * da[t]).sum();
                for (t, &dat) in da.iter().enumerate() {
                    let ds = cache.a[r * s + t] * (dat - dot);
                    if ds == 0.0 {
                        continue;
                    }
                    let erow = &he[(r * s + t) * h..(r * s + t + 1) * h];
                    let hrow_grad = &mut dh_dec[r * h..(r + 1) * h];
                    for (dg, &e) in hrow_grad.iter_mut().zip(erow) {
                        *dg += ds * e;
                    }
                    let drow = &mut dhe[(r * s + t) * h..(r * s + t + 1) * h];
                    let hdec_row = &cache.cat[r * 2 * h + h..(r + 1) * 2 * h];
                    for (d, &hv) in drow.iter_mut().zip(hdec_row) {
                        *d += ds * hv;
                    }
                }
            }
        });
        dh_dec
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn attention_weights_sum_to_one_and_mask_pads() {
        let mut rng = XorShift64::new(1);
        let (b, s, h) = (3, 5, 8);
        let at = Attention::init(h, 0.3, &mut rng);
        let hd = prop::vec_f32(&mut rng, b * h, 1.0);
        let he = prop::vec_f32(&mut rng, b * s * h, 1.0);
        let lens = vec![5, 3, 1];
        let mut t = PhaseTimer::new();
        let mut out = vec![0.0; b * h];
        let c = at.fwd(&hd, &he, &lens, b, s, &mut t, &mut out);
        for r in 0..b {
            let sum: f32 = c.a[r * s..(r + 1) * s].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            for tpos in lens[r]..s {
                assert_eq!(c.a[r * s + tpos], 0.0, "pad position got weight");
            }
        }
        assert!(out.iter().all(|v| v.abs() <= 1.0), "tanh range");
    }

    #[test]
    fn bwd_matches_finite_differences() {
        let mut rng = XorShift64::new(2);
        let (b, s, h) = (2, 3, 4);
        let at = Attention::init(h, 0.4, &mut rng);
        let hd = prop::vec_f32(&mut rng, b * h, 0.8);
        let he = prop::vec_f32(&mut rng, b * s * h, 0.8);
        let lens = vec![3, 2];

        // Loss = 0.5 Σ ĥ².
        let loss = |at: &Attention, hd: &[f32], he: &[f32]| -> f64 {
            let mut t = PhaseTimer::new();
            let mut out = vec![0.0; b * h];
            at.fwd(hd, he, &lens, b, s, &mut t, &mut out);
            0.5 * out.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
        };

        let mut t = PhaseTimer::new();
        let mut out = vec![0.0; b * h];
        let cache = at.fwd(&hd, &he, &lens, b, s, &mut t, &mut out);
        let mut grads = AttentionGrads::zeros(&at);
        let mut dhe = vec![0.0f32; b * s * h];
        let dh = at.bwd(&cache, &he, &lens, &out, b, &mut grads, &mut dhe, &mut t);

        let eps = 1e-3;
        for idx in 0..b * h {
            let mut hp = hd.clone();
            hp[idx] += eps;
            let mut hm = hd.clone();
            hm[idx] -= eps;
            let num = ((loss(&at, &hp, &he) - loss(&at, &hm, &he)) / (2.0 * eps as f64)) as f32;
            assert!((dh[idx] - num).abs() < 5e-3 * (1.0 + num.abs()),
                    "dh_dec[{idx}] {} vs {num}", dh[idx]);
        }
        for idx in (0..b * s * h).step_by(5) {
            let mut hp = he.to_vec();
            hp[idx] += eps;
            let mut hm = he.to_vec();
            hm[idx] -= eps;
            let num = ((loss(&at, &hd, &hp) - loss(&at, &hd, &hm)) / (2.0 * eps as f64)) as f32;
            assert!((dhe[idx] - num).abs() < 5e-3 * (1.0 + num.abs()),
                    "dHe[{idx}] {} vs {num}", dhe[idx]);
        }
        for idx in (0..2 * h * h).step_by(7) {
            let mut ap = at.clone();
            ap.wc[idx] += eps;
            let mut am = at.clone();
            am.wc[idx] -= eps;
            let num = ((loss(&ap, &hd, &he) - loss(&am, &hd, &he)) / (2.0 * eps as f64)) as f32;
            assert!((grads.dwc[idx] - num).abs() < 5e-3 * (1.0 + num.abs()),
                    "dWc[{idx}] {} vs {num}", grads.dwc[idx]);
        }
    }

    #[test]
    fn pad_positions_get_no_gradient() {
        let mut rng = XorShift64::new(3);
        let (b, s, h) = (1, 4, 4);
        let at = Attention::init(h, 0.4, &mut rng);
        let hd = prop::vec_f32(&mut rng, b * h, 0.8);
        let he = prop::vec_f32(&mut rng, b * s * h, 0.8);
        let lens = vec![2];
        let mut t = PhaseTimer::new();
        let mut out = vec![0.0; b * h];
        let cache = at.fwd(&hd, &he, &lens, b, s, &mut t, &mut out);
        let mut grads = AttentionGrads::zeros(&at);
        let mut dhe = vec![0.0f32; b * s * h];
        at.bwd(&cache, &he, &lens, &out, b, &mut grads, &mut dhe, &mut t);
        assert!(dhe[2 * h..].iter().all(|&v| v == 0.0),
                "padded encoder positions must get zero gradient");
    }
}
