//! Native LSTM cell with structured-sparsity-aware forward and backward
//! passes — the training engine that actually *skips* the dropped FLOPs
//! (paper §3.2), routing every GEMM through the matching Fig. 2 variant:
//!
//! * FP:  gate pre-activations via the compacted FP GEMM (column-sparse
//!   input) when the mask is structured, dense masked GEMM otherwise.
//! * BP:  `δh_{t-1} = (δg* Uᵀ) ⊙ m_h` via the compacted BP GEMM — dropped
//!   columns never computed.
//! * WG:  `δW += x_dᵀ δg*` via the compacted WG GEMM — only kept rows
//!   touched.
//!
//! The per-step math and the mask-routed GEMM dispatch live in
//! [`crate::rnn::stacked`] (shared with the full-window sequence runtime);
//! this module keeps the parameter types plus the allocating single-step
//! `cell_fwd`/`cell_bwd` convenience API. Every GEMM is charged to its
//! phase on the caller's [`PhaseTimer`], which is how the per-phase
//! speedups of Tables 1-3 are measured.

use crate::dropout::mask::Mask;
use crate::dropout::rng::XorShift64;
use crate::gemm::backend;
use crate::gemm::sparse::SparseScratch;
use crate::rnn::stacked::{
    bp_project_ws, pointwise_bwd, pointwise_fwd, project_ws, wg_project_ws,
};
use crate::train::timing::{Phase, PhaseTimer};

/// Parameters of one LSTM layer. Gate order in the fused `4H` dimension is
/// `i, f, o, g` (Eqs. 1-4), matching the Python/XLA side.
#[derive(Debug, Clone)]
pub struct LstmParams {
    pub dx: usize,
    pub h: usize,
    /// `[dx, 4h]` input-to-hidden weight.
    pub w: Vec<f32>,
    /// `[h, 4h]` hidden-to-hidden weight.
    pub u: Vec<f32>,
    /// `[4h]` bias.
    pub b: Vec<f32>,
}

impl LstmParams {
    /// Uniform `[-s, s]` init (Zaremba et al. recipe).
    pub fn init(dx: usize, h: usize, s: f32, rng: &mut XorShift64) -> LstmParams {
        LstmParams {
            dx,
            h,
            w: (0..dx * 4 * h).map(|_| rng.uniform(-s, s)).collect(),
            u: (0..h * 4 * h).map(|_| rng.uniform(-s, s)).collect(),
            b: vec![0.0; 4 * h],
        }
    }

    pub fn numel(&self) -> usize {
        self.w.len() + self.u.len() + self.b.len()
    }
}

/// Gradient accumulator matching [`LstmParams`].
#[derive(Debug, Clone)]
pub struct LstmGrads {
    pub dw: Vec<f32>,
    pub du: Vec<f32>,
    pub db: Vec<f32>,
}

impl LstmGrads {
    pub fn zeros(p: &LstmParams) -> LstmGrads {
        LstmGrads {
            dw: vec![0.0; p.w.len()],
            du: vec![0.0; p.u.len()],
            db: vec![0.0; p.b.len()],
        }
    }

    pub fn zero(&mut self) {
        self.dw.fill(0.0);
        self.du.fill(0.0);
        self.db.fill(0.0);
    }
}

/// Residuals of one forward cell step, consumed by [`cell_bwd`].
#[derive(Debug, Clone)]
pub struct CellCache {
    /// Masked layer input `x ⊙ m_x`, `[b, dx]`.
    pub xd: Vec<f32>,
    /// Masked recurrent input `h_{t-1} ⊙ m_h`, `[b, h]`.
    pub hd: Vec<f32>,
    /// Post-activation gates `[i f o g]`, `[b, 4h]`.
    pub act: Vec<f32>,
    /// Previous cell state `[b, h]`.
    pub c_prev: Vec<f32>,
    /// New cell state `[b, h]`.
    pub c: Vec<f32>,
    /// The masks used (for BP/WG routing).
    pub mx: Mask,
    pub mh: Mask,
}

/// One LSTM cell forward step (Eqs. 1-6). Returns `(h, c, cache)`.
///
/// This is the allocating single-step convenience API (unit tests, one-off
/// cells); full-window training runs through [`crate::rnn::StackedLstm`],
/// which drives the *same* underlying kernels over preallocated workspace
/// buffers — the two are bit-identical by construction (asserted by the
/// `rnn::stacked` equivalence tests).
///
/// GEMMs are charged to `Phase::Fp`; pointwise gate math is also FP (it is
/// part of the forward pass the paper times).
pub fn cell_fwd(
    p: &LstmParams,
    x: &[f32],
    h_prev: &[f32],
    c_prev: &[f32],
    mx: &Mask,
    mh: &Mask,
    b: usize,
    timer: &mut PhaseTimer,
) -> (Vec<f32>, Vec<f32>, CellCache) {
    let (dx, h) = (p.dx, p.h);
    let n4 = 4 * h;
    assert_eq!(x.len(), b * dx);
    assert_eq!(h_prev.len(), b * h);
    assert_eq!(c_prev.len(), b * h);
    assert_eq!(mx.h(), dx);
    assert_eq!(mh.h(), h);

    let be = backend::global();
    let mut scratch = SparseScratch::new();
    let mut xd = vec![0.0f32; b * dx];
    let mut hd = vec![0.0f32; b * h];
    let mut pre = vec![0.0f32; b * n4];

    timer.time(Phase::Fp, || {
        // Bias broadcast.
        for r in 0..b {
            pre[r * n4..(r + 1) * n4].copy_from_slice(&p.b);
        }
        // Materialize the masked operands (the WG residuals), then run the
        // mask-routed projections.
        xd.copy_from_slice(x);
        mx.apply(&mut xd, b);
        project_ws(be.as_ref(), &xd, &p.w, mx, b, dx, n4, &mut pre, &mut scratch);
        hd.copy_from_slice(h_prev);
        mh.apply(&mut hd, b);
        project_ws(be.as_ref(), &hd, &p.u, mh, b, h, n4, &mut pre, &mut scratch);
    });

    let mut act = vec![0.0f32; b * n4];
    let mut c = vec![0.0f32; b * h];
    let mut h_new = vec![0.0f32; b * h];
    timer.time(Phase::Fp, || {
        pointwise_fwd(h, b, &pre, c_prev, &mut act, &mut c, &mut h_new);
    });

    let cache = CellCache {
        xd,
        hd,
        act,
        c_prev: c_prev.to_vec(),
        c: c.clone(),
        mx: mx.clone(),
        mh: mh.clone(),
    };
    (h_new, c, cache)
}

/// One LSTM cell backward step (Eqs. 7-11) — the allocating single-step
/// twin of the runtime's backward kernels (see [`cell_fwd`]).
///
/// `dh`/`dc_in` are gradients flowing into `h_t`/`c_t`. Gradients for the
/// weights accumulate into `grads`. Returns `(dx, dh_prev, dc_prev)`.
pub fn cell_bwd(
    p: &LstmParams,
    cache: &CellCache,
    dh: &[f32],
    dc_in: &[f32],
    b: usize,
    grads: &mut LstmGrads,
    timer: &mut PhaseTimer,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (dx_dim, h) = (p.dx, p.h);
    let n4 = 4 * h;
    assert_eq!(dh.len(), b * h);
    assert_eq!(dc_in.len(), b * h);

    let be = backend::global();
    let mut scratch = SparseScratch::new();

    // --- BP pointwise: gate gradients (Eqs. 7-9 + nonlinearity pullback).
    let mut dpre = vec![0.0f32; b * n4];
    let mut dc_prev = dc_in.to_vec();
    timer.time(Phase::Bp, || {
        pointwise_bwd(h, b, &cache.act, &cache.c, &cache.c_prev, dh,
                      &mut dc_prev, &mut dpre);
    });

    // --- BP GEMMs (Eq. 10): input gradients, masked — output sparsity.
    let mut dx = vec![0.0f32; b * dx_dim];
    let mut dh_prev = vec![0.0f32; b * h];
    timer.time(Phase::Bp, || {
        bp_project_ws(be.as_ref(), &dpre, &p.w, &cache.mx, b, n4, dx_dim,
                      &mut dx, &mut scratch);
        bp_project_ws(be.as_ref(), &dpre, &p.u, &cache.mh, b, n4, h,
                      &mut dh_prev, &mut scratch);
    });

    // --- WG GEMMs (Eq. 11): weight gradients — row sparsity.
    timer.time(Phase::Wg, || {
        wg_project_ws(be.as_ref(), &cache.xd, &dpre, &cache.mx, b, n4,
                      &mut grads.dw, &mut scratch);
        wg_project_ws(be.as_ref(), &cache.hd, &dpre, &cache.mh, b, n4,
                      &mut grads.du, &mut scratch);
        for r in 0..b {
            for j in 0..n4 {
                grads.db[j] += dpre[r * n4 + j];
            }
        }
    });

    (dx, dh_prev, dc_prev)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dropout::mask::{ColumnMask, RandomMask};
    use crate::rnn::stacked::sigmoid;
    use crate::util::prop;

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
                    "mismatch at {i}: {x} vs {y}");
        }
    }

    fn setup(rng: &mut XorShift64, b: usize, dx: usize, h: usize)
        -> (LstmParams, Vec<f32>, Vec<f32>, Vec<f32>) {
        let p = LstmParams::init(dx, h, 0.4, rng);
        let x = prop::vec_f32(rng, b * dx, 0.8);
        let hp = prop::vec_f32(rng, b * h, 0.8);
        let cp = prop::vec_f32(rng, b * h, 0.8);
        (p, x, hp, cp)
    }

    /// Plain-Rust reference for one cell step under dense masks.
    fn ref_fwd(
        p: &LstmParams, x: &[f32], hp: &[f32], cp: &[f32],
        mxd: &[f32], mhd: &[f32], b: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let (dx, h) = (p.dx, p.h);
        let n4 = 4 * h;
        let mut ho = vec![0.0; b * h];
        let mut co = vec![0.0; b * h];
        for r in 0..b {
            for j in 0..n4 {
                let mut pre = p.b[j];
                for q in 0..dx {
                    pre += x[r * dx + q] * mxd[r * dx + q] * p.w[q * n4 + j];
                }
                for q in 0..h {
                    pre += hp[r * h + q] * mhd[r * h + q] * p.u[q * n4 + j];
                }
                if j < h {
                    co[r * h + j] = pre; // stash i pre
                }
                // store pre in a side buffer via closure-free approach:
                // recompute below instead (test-only, clarity over speed)
            }
        }
        // second pass, explicit
        for r in 0..b {
            let mut pres = vec![0.0f32; n4];
            for j in 0..n4 {
                let mut pre = p.b[j];
                for q in 0..dx {
                    pre += x[r * dx + q] * mxd[r * dx + q] * p.w[q * n4 + j];
                }
                for q in 0..h {
                    pre += hp[r * h + q] * mhd[r * h + q] * p.u[q * n4 + j];
                }
                pres[j] = pre;
            }
            for j in 0..h {
                let i_g = sigmoid(pres[j]);
                let f_g = sigmoid(pres[h + j]);
                let o_g = sigmoid(pres[2 * h + j]);
                let g_g = pres[3 * h + j].tanh();
                let c_new = f_g * cp[r * h + j] + i_g * g_g;
                co[r * h + j] = c_new;
                ho[r * h + j] = o_g * c_new.tanh();
            }
        }
        (ho, co)
    }

    #[test]
    fn fwd_matches_reference_structured() {
        prop::for_all("cell_fwd (structured) == dense reference", |rng| {
            let b = prop::usize_in(rng, 1, 5);
            let dx = prop::usize_in(rng, 2, 20);
            let h = prop::usize_in(rng, 2, 20);
            let (p, x, hp, cp) = setup(rng, b, dx, h);
            let mx = Mask::Column(ColumnMask::sample(rng, dx, 0.5));
            let mh = Mask::Column(ColumnMask::sample(rng, h, 0.5));
            let mut t = PhaseTimer::new();
            let (ho, co, _) = cell_fwd(&p, &x, &hp, &cp, &mx, &mh, b, &mut t);
            let (hr, cr) = ref_fwd(&p, &x, &hp, &cp, &mx.to_dense(b), &mh.to_dense(b), b);
            assert_close(&ho, &hr, 1e-4);
            assert_close(&co, &cr, 1e-4);
            assert!(t.fp > std::time::Duration::ZERO);
        });
    }

    #[test]
    fn fwd_matches_reference_random_mask() {
        prop::for_all("cell_fwd (random) == dense reference", |rng| {
            let b = prop::usize_in(rng, 1, 4);
            let dx = prop::usize_in(rng, 2, 16);
            let h = prop::usize_in(rng, 2, 16);
            let (p, x, hp, cp) = setup(rng, b, dx, h);
            let mx = Mask::Random(RandomMask::sample(rng, b, dx, 0.4));
            let mh = Mask::Ones { h };
            let mut t = PhaseTimer::new();
            let (ho, co, _) = cell_fwd(&p, &x, &hp, &cp, &mx, &mh, b, &mut t);
            let (hr, cr) = ref_fwd(&p, &x, &hp, &cp, &mx.to_dense(b), &mh.to_dense(b), b);
            assert_close(&ho, &hr, 1e-4);
            assert_close(&co, &cr, 1e-4);
        });
    }

    /// Finite-difference check of the full backward pass: the strongest
    /// correctness statement for the hand-derived Eqs. 7-11.
    #[test]
    fn bwd_matches_finite_differences() {
        let mut rng = XorShift64::new(31);
        let (b, dx, h) = (2, 5, 4);
        let (p, x, hp, cp) = setup(&mut rng, b, dx, h);
        let mx = Mask::Column(ColumnMask::sample(&mut rng, dx, 0.4));
        let mh = Mask::Column(ColumnMask::sample(&mut rng, h, 0.25));
        let mut t = PhaseTimer::new();

        // Loss = sum(h) + 0.5*sum(c^2); dL/dh = 1, dL/dc = c.
        let loss = |p: &LstmParams, x: &[f32], hp: &[f32], cp: &[f32]| -> f64 {
            let mut tt = PhaseTimer::new();
            let (ho, co, _) = cell_fwd(p, x, hp, cp, &mx, &mh, b, &mut tt);
            ho.iter().map(|&v| v as f64).sum::<f64>()
                + 0.5 * co.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
        };

        let (ho, co, cache) = cell_fwd(&p, &x, &hp, &cp, &mx, &mh, b, &mut t);
        let _ = ho;
        let dh = vec![1.0f32; b * h];
        let dc: Vec<f32> = co.clone();
        let mut grads = LstmGrads::zeros(&p);
        let (dxv, dhp, dcp) = cell_bwd(&p, &cache, &dh, &dc, b, &mut grads, &mut t);

        let eps = 1e-3f32;
        let _ = loss; // spot checks below re-derive losses explicitly

        // Spot-check a handful of coordinates in every gradient buffer.
        for idx in [0usize, 3, b * dx - 1] {
            let lp = {
                let mut tt = PhaseTimer::new();
                let mut xb = x.clone();
                xb[idx] += eps;
                let (ho2, co2, _) = cell_fwd(&p, &xb, &hp, &cp, &mx, &mh, b, &mut tt);
                ho2.iter().map(|&v| v as f64).sum::<f64>()
                    + 0.5 * co2.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
            };
            let lm = {
                let mut tt = PhaseTimer::new();
                let mut xb = x.clone();
                xb[idx] -= eps;
                let (ho2, co2, _) = cell_fwd(&p, &xb, &hp, &cp, &mx, &mh, b, &mut tt);
                ho2.iter().map(|&v| v as f64).sum::<f64>()
                    + 0.5 * co2.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
            };
            let numeric = ((lp - lm) / (2.0 * eps as f64)) as f32;
            assert!((dxv[idx] - numeric).abs() < 2e-2 * (1.0 + numeric.abs()),
                    "dx[{idx}]: {} vs {numeric}", dxv[idx]);
        }

        for idx in [0usize, b * h - 1] {
            let fd = |delta: f32| {
                let mut tt = PhaseTimer::new();
                let mut hb = hp.clone();
                hb[idx] += delta;
                let (ho2, co2, _) = cell_fwd(&p, &x, &hb, &cp, &mx, &mh, b, &mut tt);
                ho2.iter().map(|&v| v as f64).sum::<f64>()
                    + 0.5 * co2.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
            };
            let numeric = ((fd(eps) - fd(-eps)) / (2.0 * eps as f64)) as f32;
            assert!((dhp[idx] - numeric).abs() < 2e-2 * (1.0 + numeric.abs()),
                    "dh_prev[{idx}]: {} vs {numeric}", dhp[idx]);
        }

        for idx in [0usize, b * h - 1] {
            let fd = |delta: f32| {
                let mut tt = PhaseTimer::new();
                let mut cb = cp.clone();
                cb[idx] += delta;
                let (ho2, co2, _) = cell_fwd(&p, &x, &hp, &cb, &mx, &mh, b, &mut tt);
                ho2.iter().map(|&v| v as f64).sum::<f64>()
                    + 0.5 * co2.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
            };
            let numeric = ((fd(eps) - fd(-eps)) / (2.0 * eps as f64)) as f32;
            assert!((dcp[idx] - numeric).abs() < 2e-2 * (1.0 + numeric.abs()),
                    "dc_prev[{idx}]: {} vs {numeric}", dcp[idx]);
        }

        // Weight gradients: check a few dW / dU / db coordinates.
        for idx in [0usize, 7, p.w.len() - 1] {
            let fd = |delta: f32| {
                let mut tt = PhaseTimer::new();
                let mut pb = p.clone();
                pb.w[idx] += delta;
                let (ho2, co2, _) = cell_fwd(&pb, &x, &hp, &cp, &mx, &mh, b, &mut tt);
                ho2.iter().map(|&v| v as f64).sum::<f64>()
                    + 0.5 * co2.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
            };
            let numeric = ((fd(eps) - fd(-eps)) / (2.0 * eps as f64)) as f32;
            assert!((grads.dw[idx] - numeric).abs() < 2e-2 * (1.0 + numeric.abs()),
                    "dW[{idx}]: {} vs {numeric}", grads.dw[idx]);
        }
        for idx in [0usize, p.u.len() - 1] {
            let fd = |delta: f32| {
                let mut tt = PhaseTimer::new();
                let mut pb = p.clone();
                pb.u[idx] += delta;
                let (ho2, co2, _) = cell_fwd(&pb, &x, &hp, &cp, &mx, &mh, b, &mut tt);
                ho2.iter().map(|&v| v as f64).sum::<f64>()
                    + 0.5 * co2.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
            };
            let numeric = ((fd(eps) - fd(-eps)) / (2.0 * eps as f64)) as f32;
            assert!((grads.du[idx] - numeric).abs() < 2e-2 * (1.0 + numeric.abs()),
                    "dU[{idx}]: {} vs {numeric}", grads.du[idx]);
        }
        for idx in [0usize, 4 * h - 1] {
            let fd = |delta: f32| {
                let mut tt = PhaseTimer::new();
                let mut pb = p.clone();
                pb.b[idx] += delta;
                let (ho2, co2, _) = cell_fwd(&pb, &x, &hp, &cp, &mx, &mh, b, &mut tt);
                ho2.iter().map(|&v| v as f64).sum::<f64>()
                    + 0.5 * co2.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
            };
            let numeric = ((fd(eps) - fd(-eps)) / (2.0 * eps as f64)) as f32;
            assert!((grads.db[idx] - numeric).abs() < 2e-2 * (1.0 + numeric.abs()),
                    "db[{idx}]: {} vs {numeric}", grads.db[idx]);
        }
    }

    #[test]
    fn bwd_sparsity_structure() {
        // Paper §3.2 invariants on the native engine: dropped columns of
        // dh_prev are zero; dropped rows of dU are zero.
        let mut rng = XorShift64::new(77);
        let (b, dx, h) = (3, 8, 12);
        let (p, x, hp, cp) = setup(&mut rng, b, dx, h);
        let mx = Mask::Column(ColumnMask::sample(&mut rng, dx, 0.5));
        let mh = Mask::Column(ColumnMask::sample(&mut rng, h, 0.5));
        let mut t = PhaseTimer::new();
        let (_, co, cache) = cell_fwd(&p, &x, &hp, &cp, &mx, &mh, b, &mut t);
        let dh = vec![1.0; b * h];
        let mut grads = LstmGrads::zeros(&p);
        let (dxv, dhp, _) = cell_bwd(&p, &cache, &dh, &co, b, &mut grads, &mut t);

        let (cmx, cmh) = match (&mx, &mh) {
            (Mask::Column(a), Mask::Column(b)) => (a, b),
            _ => unreachable!(),
        };
        for j in 0..h {
            if !cmh.keeps(j) {
                for r in 0..b {
                    assert_eq!(dhp[r * h + j], 0.0, "dh_prev col {j}");
                }
                assert!(grads.du[j * 4 * h..(j + 1) * 4 * h].iter().all(|&v| v == 0.0),
                        "dU row {j}");
            }
        }
        for j in 0..dx {
            if !cmx.keeps(j) {
                for r in 0..b {
                    assert_eq!(dxv[r * dx + j], 0.0, "dx col {j}");
                }
                assert!(grads.dw[j * 4 * h..(j + 1) * 4 * h].iter().all(|&v| v == 0.0),
                        "dW row {j}");
            }
        }
        // WG time was charged.
        assert!(t.wg > std::time::Duration::ZERO);
    }
}
