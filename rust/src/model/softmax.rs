//! Fused log-softmax + cross-entropy, the loss head of all three tasks.
//! Numerically stable (max-subtraction); backward is `softmax(z) - onehot`.

/// Forward: summed NLL over the batch and the softmax probabilities cache.
/// `logits: [b, v]`, `targets: [b]` (entries `< 0` are ignored — padding).
pub fn ce_fwd(logits: &[f32], targets: &[i32], b: usize, v: usize) -> (f64, Vec<f32>) {
    let mut probs = vec![0.0f32; b * v];
    let nll = ce_fwd_into(logits, targets, b, v, &mut probs);
    (nll, probs)
}

/// [`ce_fwd`] into a caller-provided probabilities buffer — the
/// allocation-free form the `rnn::` sequence runtime's heads use.
pub fn ce_fwd_into(
    logits: &[f32], targets: &[i32], b: usize, v: usize, probs: &mut [f32],
) -> f64 {
    assert_eq!(logits.len(), b * v);
    assert_eq!(targets.len(), b);
    assert_eq!(probs.len(), b * v);
    let mut nll = 0.0f64;
    for r in 0..b {
        let row = &logits[r * v..(r + 1) * v];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f64;
        for &z in row {
            denom += ((z - mx) as f64).exp();
        }
        let log_denom = denom.ln();
        let prow = &mut probs[r * v..(r + 1) * v];
        for (p, &z) in prow.iter_mut().zip(row) {
            *p = (((z - mx) as f64 - log_denom).exp()) as f32;
        }
        let t = targets[r];
        if t >= 0 {
            let t = t as usize;
            assert!(t < v, "target {t} out of range");
            nll -= (row[t] - mx) as f64 - log_denom;
        }
    }
    nll
}

/// Backward: `dlogits = (probs - onehot(target)) * scale` per row; padded
/// rows (target < 0) get zero gradient.
pub fn ce_bwd(probs: &[f32], targets: &[i32], b: usize, v: usize, scale: f32) -> Vec<f32> {
    let mut d = vec![0.0f32; b * v];
    ce_bwd_into(probs, targets, b, v, scale, &mut d);
    d
}

/// [`ce_bwd`] into a caller-provided gradient buffer (fully overwritten).
pub fn ce_bwd_into(
    probs: &[f32], targets: &[i32], b: usize, v: usize, scale: f32, d: &mut [f32],
) {
    assert_eq!(probs.len(), b * v);
    assert_eq!(d.len(), b * v);
    for r in 0..b {
        let t = targets[r];
        let drow = &mut d[r * v..(r + 1) * v];
        if t < 0 {
            drow.fill(0.0);
            continue;
        }
        drow.copy_from_slice(&probs[r * v..(r + 1) * v]);
        drow[t as usize] -= 1.0;
        for x in drow.iter_mut() {
            *x *= scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dropout::rng::XorShift64;
    use crate::util::prop;

    #[test]
    fn uniform_logits_give_ln_v() {
        let (b, v) = (3, 50);
        let (nll, probs) = ce_fwd(&vec![0.7; b * v], &vec![5; b], b, v);
        assert!((nll / b as f64 - (v as f64).ln()).abs() < 1e-9);
        assert!(probs.iter().all(|&p| (p - 1.0 / v as f32).abs() < 1e-6));
    }

    #[test]
    fn probabilities_sum_to_one() {
        prop::for_all("softmax rows sum to 1", |rng| {
            let b = prop::usize_in(rng, 1, 5);
            let v = prop::usize_in(rng, 2, 40);
            let logits = prop::vec_f32(rng, b * v, 5.0);
            let targets: Vec<i32> = (0..b).map(|_| rng.below(v) as i32).collect();
            let (_, probs) = ce_fwd(&logits, &targets, b, v);
            for r in 0..b {
                let s: f32 = probs[r * v..(r + 1) * v].iter().sum();
                assert!((s - 1.0).abs() < 1e-4, "row {r} sums to {s}");
            }
        });
    }

    #[test]
    fn confident_correct_prediction_has_low_loss() {
        let v = 10;
        let mut logits = vec![0.0f32; v];
        logits[3] = 20.0;
        let (nll, _) = ce_fwd(&logits, &[3], 1, v);
        assert!(nll < 1e-3, "nll={nll}");
    }

    #[test]
    fn bwd_matches_finite_differences() {
        let mut rng = XorShift64::new(4);
        let (b, v) = (2, 7);
        let logits = prop::vec_f32(&mut rng, b * v, 2.0);
        let targets = vec![1, 6];
        let (_, probs) = ce_fwd(&logits, &targets, b, v);
        let d = ce_bwd(&probs, &targets, b, v, 1.0);
        let eps = 1e-3f32;
        for idx in 0..b * v {
            let mut lp = logits.clone();
            lp[idx] += eps;
            let mut lm = logits.clone();
            lm[idx] -= eps;
            let num = ((ce_fwd(&lp, &targets, b, v).0 - ce_fwd(&lm, &targets, b, v).0)
                / (2.0 * eps as f64)) as f32;
            assert!((d[idx] - num).abs() < 1e-3 * (1.0 + num.abs()),
                    "dlogits[{idx}] {} vs {num}", d[idx]);
        }
    }

    #[test]
    fn padding_rows_ignored() {
        let (b, v) = (2, 5);
        let logits = vec![1.0; b * v];
        let (nll, probs) = ce_fwd(&logits, &[2, -1], b, v);
        assert!((nll - (v as f64).ln()).abs() < 1e-9); // only row 0 counted
        let d = ce_bwd(&probs, &[2, -1], b, v, 1.0);
        assert!(d[v..].iter().all(|&x| x == 0.0), "padded row must get no grad");
    }

    #[test]
    fn large_logits_are_stable() {
        let (nll, probs) = ce_fwd(&[1e4, -1e4, 0.0], &[0], 1, 3);
        assert!(nll.is_finite());
        assert!(probs.iter().all(|p| p.is_finite()));
        assert!((probs[0] - 1.0).abs() < 1e-6);
    }
}
