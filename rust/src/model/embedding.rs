//! Token embedding layer: gather on the forward pass, scatter-add on the
//! backward pass (only rows of observed tokens receive gradient).

use crate::dropout::rng::XorShift64;

/// `[vocab, dim]` embedding table.
#[derive(Debug, Clone)]
pub struct Embedding {
    pub vocab: usize,
    pub dim: usize,
    pub w: Vec<f32>,
}

impl Embedding {
    pub fn init(vocab: usize, dim: usize, s: f32, rng: &mut XorShift64) -> Embedding {
        Embedding {
            vocab,
            dim,
            w: (0..vocab * dim).map(|_| rng.uniform(-s, s)).collect(),
        }
    }

    /// Look up `ids` (length n) into a `[n, dim]` buffer.
    pub fn fwd(&self, ids: &[i32], out: &mut [f32]) {
        assert_eq!(out.len(), ids.len() * self.dim);
        for (r, &id) in ids.iter().enumerate() {
            let id = id as usize;
            assert!(id < self.vocab, "token id {id} out of range");
            out[r * self.dim..(r + 1) * self.dim]
                .copy_from_slice(&self.w[id * self.dim..(id + 1) * self.dim]);
        }
    }

    /// Scatter-add `dout[n, dim]` into the gradient table `dw[vocab, dim]`.
    pub fn bwd(&self, ids: &[i32], dout: &[f32], dw: &mut [f32]) {
        assert_eq!(dout.len(), ids.len() * self.dim);
        assert_eq!(dw.len(), self.vocab * self.dim);
        for (r, &id) in ids.iter().enumerate() {
            let id = id as usize;
            let dst = &mut dw[id * self.dim..(id + 1) * self.dim];
            let src = &dout[r * self.dim..(r + 1) * self.dim];
            for (d, &s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fwd_gathers_rows() {
        let mut rng = XorShift64::new(1);
        let e = Embedding::init(10, 4, 0.5, &mut rng);
        let mut out = vec![0.0; 3 * 4];
        e.fwd(&[7, 0, 7], &mut out);
        assert_eq!(&out[0..4], &e.w[28..32]);
        assert_eq!(&out[4..8], &e.w[0..4]);
        assert_eq!(&out[8..12], &e.w[28..32]);
    }

    #[test]
    fn bwd_scatter_adds_duplicates() {
        let mut rng = XorShift64::new(2);
        let e = Embedding::init(5, 2, 0.5, &mut rng);
        let mut dw = vec![0.0; 10];
        e.bwd(&[3, 3, 1], &[1.0, 2.0, 10.0, 20.0, 0.5, 0.25], &mut dw);
        assert_eq!(&dw[6..8], &[11.0, 22.0]); // row 3 accumulated twice
        assert_eq!(&dw[2..4], &[0.5, 0.25]);
        assert!(dw[0..2].iter().all(|&v| v == 0.0)); // untouched rows zero
    }

    #[test]
    #[should_panic]
    fn out_of_range_id_panics() {
        let mut rng = XorShift64::new(3);
        let e = Embedding::init(4, 2, 0.5, &mut rng);
        let mut out = vec![0.0; 2];
        e.fwd(&[4], &mut out);
    }
}
