//! Thread-local cycle metering for the systolic engine.
//!
//! Every GEMM the [`crate::gemm::backend::Systolic`] engine executes
//! charges its modeled [`GemmCost`] here, attributed to the training phase
//! the enclosing [`crate::train::timing::PhaseTimer::time`] scope is
//! charging (`None` → [`Phase::Other`]). The totals flow out through the
//! benches' `--json-out` records (`util::bench_util::cycle_fields`), which
//! is how `rnn_window` and `systolic_ablation` emit cycle trajectories
//! next to the wall-clock ones.
//!
//! The meter is thread-local because the systolic engine is a serial
//! device model — the whole training window runs on the caller's thread —
//! so no synchronization is needed and the steady-state zero-allocation
//! contract of the `rnn::` runtime holds trivially.

use std::cell::Cell;

use crate::systolic::model::GemmCost;
use crate::train::timing::{self, Phase};

/// Accumulated cycle totals for one phase bucket.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PhaseCycles {
    /// Naive-schedule cycles including memory stalls (`GemmCost::cycles`).
    pub cycles: u64,
    /// Double-buffered-schedule cycles (`GemmCost::db_cycles`).
    pub db_cycles: u64,
    /// Memory-stall cycles the naive schedule paid.
    pub stall_cycles: u64,
    /// Useful multiply-accumulates.
    pub macs: u64,
    /// Number of GEMM calls charged.
    pub gemms: u64,
}

impl PhaseCycles {
    pub const ZERO: PhaseCycles =
        PhaseCycles { cycles: 0, db_cycles: 0, stall_cycles: 0, macs: 0, gemms: 0 };

    fn charge(&mut self, cost: &GemmCost) {
        self.cycles += cost.cycles;
        self.db_cycles += cost.db_cycles();
        self.stall_cycles += cost.stall_cycles();
        self.macs += cost.macs;
        self.gemms += 1;
    }

    fn merged(self, other: PhaseCycles) -> PhaseCycles {
        PhaseCycles {
            cycles: self.cycles + other.cycles,
            db_cycles: self.db_cycles + other.db_cycles,
            stall_cycles: self.stall_cycles + other.stall_cycles,
            macs: self.macs + other.macs,
            gemms: self.gemms + other.gemms,
        }
    }
}

/// Per-phase cycle totals, in the paper's FP/BP/WG reporting split.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CycleTotals {
    pub fp: PhaseCycles,
    pub bp: PhaseCycles,
    pub wg: PhaseCycles,
    pub other: PhaseCycles,
}

impl CycleTotals {
    pub const ZERO: CycleTotals = CycleTotals {
        fp: PhaseCycles::ZERO,
        bp: PhaseCycles::ZERO,
        wg: PhaseCycles::ZERO,
        other: PhaseCycles::ZERO,
    };

    pub fn get(&self, phase: Phase) -> PhaseCycles {
        match phase {
            Phase::Fp => self.fp,
            Phase::Bp => self.bp,
            Phase::Wg => self.wg,
            Phase::Other => self.other,
        }
    }

    /// Sum across all phase buckets.
    pub fn total(&self) -> PhaseCycles {
        self.fp.merged(self.bp).merged(self.wg).merged(self.other)
    }
}

thread_local! {
    static TOTALS: Cell<CycleTotals> = const { Cell::new(CycleTotals::ZERO) };
    /// Nesting depth of active [`fused_step_scope`]s: while positive,
    /// per-call [`CycleMeter::charge`]s are dropped in favour of the
    /// scope's single combined charge.
    static SUPPRESS: Cell<u32> = const { Cell::new(0) };
}

/// RAII half of [`fused_step_scope`]: suppresses per-call charges for its
/// lifetime and charges the one combined fused-step cost on drop.
pub struct FusedChargeScope {
    cost: Option<GemmCost>,
}

impl Drop for FusedChargeScope {
    fn drop(&mut self) {
        if let Some(cost) = self.cost.take() {
            SUPPRESS.with(|s| s.set(s.get() - 1));
            CycleMeter::charge(&cost);
        }
    }
}

/// Treat every GEMM charged inside this scope as one fused step of the
/// given combined cost (the `b × (kx + kh) × 4h` semantic GEMM of
/// `GemmBackend::fused_step_cost`): per-call charges are suppressed and
/// `cost` is charged once when the scope drops, still inside the
/// enclosing phase-timer scope. With `cost = None` (every engine that
/// does not meter cycles) the scope is a no-op and per-call charges pass
/// through — so the wrapper is safe to install unconditionally around the
/// split projection path in `rnn::stacked`.
pub fn fused_step_scope(cost: Option<GemmCost>) -> FusedChargeScope {
    if cost.is_some() {
        SUPPRESS.with(|s| s.set(s.get() + 1));
    }
    FusedChargeScope { cost }
}

/// Handle to this thread's cycle totals.
///
/// Typical bench flow: `CycleMeter::reset()` before the measured window,
/// run it under the systolic backend, `CycleMeter::snapshot()` after.
pub struct CycleMeter;

impl CycleMeter {
    /// Charge one GEMM's modeled cost to the phase the enclosing
    /// `PhaseTimer::time` scope is attributing (or `Other` outside any).
    /// Inside a [`fused_step_scope`] the per-call charge is dropped — the
    /// scope charges its combined fused-step cost instead.
    pub fn charge(cost: &GemmCost) {
        if SUPPRESS.with(Cell::get) > 0 {
            return;
        }
        let phase = timing::current_phase().unwrap_or(Phase::Other);
        TOTALS.with(|t| {
            let mut totals = t.get();
            match phase {
                Phase::Fp => totals.fp.charge(cost),
                Phase::Bp => totals.bp.charge(cost),
                Phase::Wg => totals.wg.charge(cost),
                Phase::Other => totals.other.charge(cost),
            }
            t.set(totals);
        });
    }

    /// This thread's accumulated totals.
    pub fn snapshot() -> CycleTotals {
        TOTALS.with(Cell::get)
    }

    /// Zero the totals, returning what was accumulated.
    pub fn reset() -> CycleTotals {
        TOTALS.with(|t| t.replace(CycleTotals::ZERO))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::systolic::model::SystolicArray;
    use crate::train::timing::PhaseTimer;

    #[test]
    fn charges_attribute_to_the_enclosing_phase_scope() {
        CycleMeter::reset();
        let arr = SystolicArray::new(128);
        let cost = arr.gemm(4, 64, 64);
        let mut timer = PhaseTimer::new();
        timer.time(Phase::Fp, || CycleMeter::charge(&cost));
        timer.time(Phase::Fp, || CycleMeter::charge(&cost));
        timer.time(Phase::Wg, || CycleMeter::charge(&cost));
        CycleMeter::charge(&cost); // outside any scope -> Other

        let t = CycleMeter::reset();
        assert_eq!(t.fp.gemms, 2);
        assert_eq!(t.fp.cycles, 2 * cost.cycles);
        assert_eq!(t.fp.macs, 2 * cost.macs);
        assert_eq!(t.wg.gemms, 1);
        assert_eq!(t.bp, PhaseCycles::ZERO);
        assert_eq!(t.other.gemms, 1);
        assert_eq!(t.total().gemms, 4);
        assert_eq!(t.total().cycles, 4 * cost.cycles);
        // reset() cleared the totals.
        assert_eq!(CycleMeter::snapshot(), CycleTotals::ZERO);
    }

    #[test]
    fn fused_scope_replaces_per_call_charges_with_one_combined() {
        CycleMeter::reset();
        let arr = SystolicArray::new(128);
        let combined = arr.gemm(4, 96, 256);
        let mut timer = PhaseTimer::new();
        timer.time(Phase::Fp, || {
            let _scope = fused_step_scope(Some(combined));
            // The split path's two projection charges — both suppressed.
            CycleMeter::charge(&arr.gemm(4, 64, 256));
            CycleMeter::charge(&arr.gemm(4, 32, 256));
        });
        let t = CycleMeter::reset();
        assert_eq!(t.fp.gemms, 1, "one semantic GEMM, not two");
        assert_eq!(t.fp.cycles, combined.cycles);
        assert_eq!(t.fp.macs, combined.macs);
        assert_eq!(t.total().gemms, 1);
    }

    #[test]
    fn fused_scope_with_none_cost_passes_charges_through() {
        CycleMeter::reset();
        let cost = SystolicArray::new(64).gemm(2, 16, 32);
        {
            let _scope = fused_step_scope(None);
            CycleMeter::charge(&cost);
        }
        let t = CycleMeter::reset();
        assert_eq!(t.total().gemms, 1, "None scope must be a no-op");
        assert_eq!(t.total().cycles, cost.cycles);
    }

    #[test]
    fn charges_resume_after_the_fused_scope_drops() {
        CycleMeter::reset();
        let arr = SystolicArray::new(64);
        let combined = arr.gemm(2, 24, 64);
        {
            let _scope = fused_step_scope(Some(combined));
            CycleMeter::charge(&arr.gemm(2, 16, 64));
        }
        CycleMeter::charge(&arr.gemm(2, 8, 64));
        let t = CycleMeter::reset();
        assert_eq!(t.total().gemms, 2, "scope charge + post-scope charge");
        assert_eq!(t.total().cycles, combined.cycles + arr.gemm(2, 8, 64).cycles);
    }

    #[test]
    fn snapshot_does_not_clear() {
        CycleMeter::reset();
        let cost = SystolicArray::new(64).gemm(2, 8, 8);
        CycleMeter::charge(&cost);
        assert_eq!(CycleMeter::snapshot().total().gemms, 1);
        assert_eq!(CycleMeter::snapshot().total().gemms, 1);
        CycleMeter::reset();
    }
}
