//! Weight-stationary tile-schedule execution — the numeric half of the
//! [`crate::gemm::backend::Systolic`] engine.
//!
//! The streamed kernels here walk the schedule the cycle model in
//! [`crate::systolic::model`] charges for: the output is split into
//! `A`-wide column strips (one PE-array width each); within a strip, the
//! `A`-deep weight tiles of one drain pass are filled in contraction
//! order and each batch row block streams through them, the partial sums
//! chaining from tile to tile down the PE columns (the double-buffer
//! hand-off — arithmetic order is independent of the tile subdivision,
//! so the loop fuses the pass's tiles); the accumulated strip then
//! drains into `C`.
//!
//! **Bit-identity contract.** Two alignment choices make every output
//! element see *exactly* the accumulation order of the `Reference`
//! blocked kernels (`dense::matmul_acc` / `dense::matmul_idx_rows_acc`):
//!
//! * A drain pass is [`dense::KC`] contraction rows — the reference
//!   kernels' cache-block grouping — and passes run in ascending order.
//! * Within a pass, outputs in a full [`dense::MR`]`×`[`dense::NR`]
//!   micro-tile accumulate in PE registers and drain once (`C += acc`),
//!   exactly like `micro_4x16`; fringe outputs (edge rows/columns)
//!   accumulate directly into `C`, exactly like `micro_edge`/`idx_micro`.
//!   Strip widths are multiples of [`dense::NR`] ([`valid_array_dim`]),
//!   so the full/edge classification of every element matches the
//!   reference kernels', and row blocks start at multiples of
//!   [`dense::MR`] just like theirs.
//!
//! Row/column tile boundaries never affect per-element accumulation
//! order beyond that classification, so the engine is bit-identical to
//! the `Reference` family (asserted across ragged shapes by
//! `tests/backend_systolic.rs`). The transposed kernels (`a_bt`, `at_b`,
//! `a_bt_idx`) already map one-to-one onto a stationary-operand walk
//! with reference accumulation order, so the engine reuses the `dense::`
//! kernels for them directly (the same statement the `Simd` engine makes
//! for its BP/WG kernels). Everything here is heap-allocation-free: the
//! drain accumulator is one stack micro-tile, so the `rnn::` runtime's
//! steady-state zero-allocation contract holds.

use crate::gemm::dense::{self, KC, MR, NR};

/// True when an `A×A` array can drive the bit-identical schedule: strip
/// widths must be multiples of the reference micro-tile width so the
/// full/edge drain classification lines up (every realistic PE array —
/// 16, 32, 64, 128, 256, ... — qualifies).
pub fn valid_array_dim(a: usize) -> bool {
    a >= NR && a % NR == 0
}

/// `c += a[M,K] @ b[K,N]` through the weight-stationary tile schedule of
/// an `A×A` array.
pub fn stream_matmul_acc(
    a_dim: usize,
    a: &[f32], b: &[f32], c: &mut [f32],
    m: usize, k: usize, n: usize,
) {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert_eq!(c.len(), m * n, "C shape mismatch");
    stream_impl(a_dim, a, b, None, c, m, k, n);
}

/// `c[M,N] = a @ b` (overwrites `c`) through the same schedule.
pub fn stream_matmul(
    a_dim: usize,
    a: &[f32], b: &[f32], c: &mut [f32],
    m: usize, k: usize, n: usize,
) {
    c.fill(0.0);
    stream_matmul_acc(a_dim, a, b, c, m, k, n);
}

/// `c += a[M,KK] @ b[keep,:]` — the FP compaction stream: only the kept
/// rows of `b[K,N]` are ever filled into the array, so an empty keep-list
/// loads zero weight tiles and leaves `c` untouched (exactly what the
/// cycle model charges for it).
pub fn stream_matmul_idx_rows_acc(
    a_dim: usize,
    a: &[f32], b: &[f32], keep: &[u32], c: &mut [f32],
    m: usize, n: usize,
) {
    let kk = keep.len();
    assert_eq!(a.len(), m * kk, "A shape mismatch");
    assert_eq!(c.len(), m * n, "C shape mismatch");
    stream_impl(a_dim, a, b, Some(keep), c, m, kk, n);
}

/// Shared schedule walk. `keep` resolves contraction index `p` to a weight
/// row of `b` (`None` = the identity walk of a dense `[K, N]` operand).
///
/// The walk computes tile coordinates in fill/stream/drain order and
/// drives the *reference micro-kernels themselves* over them —
/// `micro_4x16` (full PE register tile), `micro_edge` (fringe, with its
/// zero-operand skip), `idx_micro` (keep-indexed walk) — so the engine's
/// bit-identity to the `Reference` family holds by construction, not by
/// a parallel re-implementation that could drift.
#[allow(clippy::too_many_arguments)]
fn stream_impl(
    a_dim: usize,
    a: &[f32], b: &[f32], keep: Option<&[u32]>, c: &mut [f32],
    m: usize, k: usize, n: usize,
) {
    assert!(valid_array_dim(a_dim), "PE array dim {a_dim} not a multiple of {NR}");
    let mut j0 = 0;
    while j0 < n {
        let nw = a_dim.min(n - j0); // column strip: one array width
        let mut p0 = 0;
        while p0 < k {
            // One drain pass: the reference kernels' KC contraction
            // grouping (the pass's A-deep weight tiles chain through the
            // PE columns; the chain order equals plain ascending p).
            let kc = KC.min(k - p0);
            let mut i0 = 0;
            while i0 < m {
                let mr = MR.min(m - i0);
                let mut jr = 0;
                while jr < nw {
                    let nr = NR.min(nw - jr);
                    match keep {
                        Some(kp) => dense::idx_micro(
                            a, b, kp, c, k, n, i0, p0, j0 + jr, mr, kc, nr,
                        ),
                        None if mr == MR && nr == NR => dense::micro_4x16(
                            a, b, c, k, n, i0, p0, j0 + jr, kc,
                        ),
                        None => dense::micro_edge(
                            a, b, c, k, n, i0, p0, j0 + jr, mr, kc, nr,
                        ),
                    }
                    jr += NR;
                }
                i0 += MR;
            }
            p0 += kc;
        }
        j0 += nw;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dropout::mask::ColumnMask;
    use crate::util::prop;

    #[test]
    fn stream_matmul_bitwise_equals_reference_across_kc_boundary() {
        // Shapes straddling the KC=256 drain boundary, the strip width,
        // and the 4×16 micro-tile fringe (with a non-zero C, where a
        // wrong full/edge classification or drain grouping shows up).
        prop::for_all("systolic stream == dense blocked (bitwise)", |rng| {
            let m = prop::usize_in(rng, 1, 21);
            let k = prop::usize_in(rng, 200, 300);
            let n = prop::usize_in(rng, 1, 40);
            let a_dim = [16, 128, 256][prop::usize_in(rng, 0, 2)];
            let a = prop::vec_f32(rng, m * k, 1.0);
            let b = prop::vec_f32(rng, k * n, 1.0);
            let prior = prop::vec_f32(rng, m * n, 1.0);
            let mut c1 = vec![0.0; m * n];
            let mut c2 = vec![0.0; m * n];
            dense::matmul(&a, &b, &mut c1, m, k, n);
            stream_matmul(a_dim, &a, &b, &mut c2, m, k, n);
            assert_eq!(c1, c2, "matmul m={m} k={k} n={n} A={a_dim}");

            let mut c1 = prior.clone();
            let mut c2 = prior;
            dense::matmul_acc(&a, &b, &mut c1, m, k, n);
            stream_matmul_acc(a_dim, &a, &b, &mut c2, m, k, n);
            assert_eq!(c1, c2, "matmul_acc m={m} k={k} n={n} A={a_dim}");
        });
    }

    #[test]
    fn stream_idx_rows_bitwise_equals_reference() {
        prop::for_all("systolic idx stream == dense idx (bitwise)", |rng| {
            let m = prop::usize_in(rng, 1, 12);
            // kk reaches past KC=256 so the idx stream crosses a drain
            // boundary too.
            let h = prop::usize_in(rng, 2, 560);
            let n = prop::usize_in(rng, 1, 32);
            let mask = ColumnMask::sample(rng, h, 0.5);
            let kk = mask.kept();
            let a = prop::vec_f32(rng, m * kk, 1.0);
            let b = prop::vec_f32(rng, h * n, 1.0);
            let prior = prop::vec_f32(rng, m * n, 1.0);
            let mut c1 = prior.clone();
            let mut c2 = prior;
            dense::matmul_idx_rows_acc(&a, &b, &mask.keep, &mut c1, m, n);
            stream_matmul_idx_rows_acc(128, &a, &b, &mask.keep, &mut c2, m, n);
            assert_eq!(c1, c2, "m={m} h={h} n={n} kk={kk}");
        });
    }

    #[test]
    fn empty_keep_list_streams_nothing() {
        let (m, n) = (3, 5);
        let b = vec![1.0f32; 7 * n];
        let prior: Vec<f32> = (0..m * n).map(|i| i as f32).collect();
        let mut c = prior.clone();
        stream_matmul_idx_rows_acc(128, &[], &b, &[], &mut c, m, n);
        assert_eq!(c, prior, "empty keep-list must leave C untouched");
    }

    #[test]
    fn array_dim_validity() {
        for a in [16, 32, 64, 128, 256, 512] {
            assert!(valid_array_dim(a), "{a}");
        }
        for a in [0, 1, 8, 20, 100] {
            assert!(!valid_array_dim(a), "{a}");
        }
    }
}
