//! Cycle model of a weight-stationary `A×A` systolic array (TPU-MXU-like).
//!
//! The paper argues (§1, §3) that its structured dropout pattern "is also
//! well-suited to be leveraged in systolic array-based computations": a
//! column-compacted GEMM shrinks the contraction dimension `K → kK` and
//! therefore the number of weight tiles to fill and drain, while
//! unstructured sparsity admits no tile skipping on a rigid dataflow. This
//! module quantifies that claim; [`crate::gemm::backend::Systolic`] charges
//! these costs per executed GEMM through the thread-local
//! [`crate::systolic::CycleMeter`].
//!
//! Per weight tile of depth `d ≤ A` rows and width `w ≤ A` columns, the
//! standard weight-stationary pipeline costs
//!
//! ```text
//!   fill (d cycles) + stream (M cycles) + drain (w cycles)
//! ```
//!
//! Fill/drain are charged per *row actually loaded* (edge tiles cost their
//! real depth, not a padded `A`), which makes the naive-schedule cost
//! **strictly monotonic in the kept contraction rows** — every kept unit
//! either deepens an edge tile or opens a new one. Summed over the tile
//! grid the closed form is
//!
//! ```text
//!   compute = ⌈N/A⌉·K + ⌈K/A⌉·N + ⌈K/A⌉·⌈N/A⌉·M
//! ```
//!
//! which reduces to the PR-4 upper bound `⌈K/A⌉·⌈N/A⌉·(M + 2A)` on
//! tile-aligned shapes. Two refinements are modeled alongside:
//!
//! * **Double buffering** ([`GemmCost::db_compute_cycles`]): the next
//!   tile's fill overlaps the current stream, so a tile column costs
//!   `d₀ + Σ max(M, d_next) + M + w` instead of paying every fill
//!   serially. Always ≤ the naive schedule.
//! * **Memory stalls** ([`GemmCost::mem_cycles`]): the tile traffic
//!   (weights once, activations once per tile column, results once) over a
//!   `bytes_per_cycle` off-chip path. Total cost is roofline-style:
//!   `cycles = max(compute, mem)`; [`SystolicArray::new`] disables the
//!   memory model (`bytes_per_cycle = 0`) and reproduces the pure-compute
//!   shape argument.

/// Systolic array configuration.
#[derive(Debug, Clone, Copy)]
pub struct SystolicArray {
    /// PE array dimension (A×A). TPU-v2-like default: 128.
    pub a: usize,
    /// Off-chip bytes per cycle feeding the fill/stream/drain paths;
    /// `0` disables the memory-stall term (infinite bandwidth).
    pub bytes_per_cycle: usize,
}

/// Cost estimate of one GEMM on the array.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GemmCost {
    /// Total modeled cycles of the naive schedule: `max(compute, mem)`.
    pub cycles: u64,
    /// Pure-compute cycles of the naive (non-overlapped) fill/stream/drain
    /// schedule.
    pub compute_cycles: u64,
    /// Compute cycles with the next tile's fill double-buffered under the
    /// current stream; `≤ compute_cycles`.
    pub db_compute_cycles: u64,
    /// Cycles the memory system needs for the tile traffic (0 when the
    /// memory model is disabled). `cycles - compute_cycles` is the stall.
    pub mem_cycles: u64,
    /// Useful multiply-accumulates.
    pub macs: u64,
    /// Fraction of peak MACs achieved: `macs / (cycles · A²)`; 0 for
    /// empty work.
    pub utilization: f64,
}

impl GemmCost {
    /// The all-zero cost of an empty GEMM (`m`, `k`, or `n` of 0 — e.g.
    /// an empty keep-list: no weight tiles to fill, nothing to stream).
    pub const ZERO: GemmCost = GemmCost {
        cycles: 0,
        compute_cycles: 0,
        db_compute_cycles: 0,
        mem_cycles: 0,
        macs: 0,
        utilization: 0.0,
    };

    /// Memory-stall cycles the naive schedule pays: `cycles - compute`.
    pub fn stall_cycles(&self) -> u64 {
        self.cycles - self.compute_cycles
    }

    /// Total cycles of the double-buffered schedule under the same memory
    /// model: `max(db_compute, mem)`.
    pub fn db_cycles(&self) -> u64 {
        self.db_compute_cycles.max(self.mem_cycles)
    }
}

impl SystolicArray {
    /// Pure-compute model (no memory stalls) — the upper bound on
    /// achievable utilization, the right basis for a *shape* argument
    /// (dense vs compacted ratios).
    pub fn new(a: usize) -> SystolicArray {
        SystolicArray::with_bandwidth(a, 0)
    }

    /// Model with a finite off-chip path of `bytes_per_cycle` (0 keeps the
    /// memory model disabled).
    pub fn with_bandwidth(a: usize, bytes_per_cycle: usize) -> SystolicArray {
        assert!(a > 0);
        SystolicArray { a, bytes_per_cycle }
    }

    /// Cost of a dense `[m,k]·[k,n]` GEMM.
    pub fn gemm(&self, m: usize, k: usize, n: usize) -> GemmCost {
        if m == 0 || k == 0 || n == 0 {
            return GemmCost::ZERO;
        }
        let a = self.a as u64;
        let (m, k, n) = (m as u64, k as u64, n as u64);
        let tiles_k = k.div_ceil(a);
        let tiles_n = n.div_ceil(a);

        // Naive schedule: Σ over the tile grid of (depth + M + width);
        // per-row fill/drain collapses the sums to K and N.
        let compute = tiles_n * k + tiles_k * n + tiles_k * tiles_n * m;

        // Double-buffered schedule, per tile column: first fill serial,
        // every later fill hidden under the preceding stream (a stream
        // shorter than the next fill still waits for it), one final
        // stream + per-row drain.
        let d_last = k - (tiles_k - 1) * a;
        let col_fixed = if tiles_k == 1 {
            k + m
        } else {
            a + (tiles_k - 2) * m.max(a) + m.max(d_last) + m
        };
        let db_compute = tiles_n * col_fixed + n;

        // Memory traffic: weights once, activations once per tile column,
        // results once.
        let mem = if self.bytes_per_cycle == 0 {
            0
        } else {
            let bytes = 4 * (k * n + tiles_n * m * k + m * n);
            bytes.div_ceil(self.bytes_per_cycle as u64)
        };

        let cycles = compute.max(mem);
        let macs = m * k * n;
        GemmCost {
            cycles,
            compute_cycles: compute,
            db_compute_cycles: db_compute,
            mem_cycles: mem,
            macs,
            utilization: macs as f64 / (cycles as f64 * (a * a) as f64),
        }
    }

    /// Cost of the same GEMM after column compaction to `keep` of the `k`
    /// contraction rows (the paper's FP input sparsity): fewer weight
    /// rows to fill, fewer tiles to drain, same per-tile stream length.
    /// `keep = 0` is the explicitly-empty plan — zero stream tiles, zero
    /// cycles — not a phantom one-row contraction.
    pub fn gemm_compacted(&self, m: usize, k: usize, n: usize, keep: usize) -> GemmCost {
        assert!(keep <= k, "keep list longer than the contraction dim");
        self.gemm(m, keep, n)
    }

    /// Dense-vs-compacted speedup for a keep rate `1-p`.
    pub fn compaction_speedup(&self, m: usize, k: usize, n: usize, p: f32) -> f64 {
        let keep = crate::dropout::mask::keep_count(k, p);
        let dense = self.gemm(m, k, n);
        let comp = self.gemm_compacted(m, k, n, keep);
        dense.cycles as f64 / comp.cycles as f64
    }

    /// Cost under *unstructured* sparsity: random per-element zeros admit
    /// no tile skipping on a rigid systolic dataflow, so the dense cost is
    /// paid regardless (the paper's motivating contrast in §1).
    pub fn gemm_unstructured(&self, m: usize, k: usize, n: usize, _density: f64) -> GemmCost {
        self.gemm(m, k, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_cycles_scale_with_tiles() {
        let arr = SystolicArray::new(128);
        let c1 = arr.gemm(20, 128, 128);
        let c2 = arr.gemm(20, 256, 128);
        assert_eq!(c2.cycles, 2 * c1.cycles);
        let c4 = arr.gemm(20, 256, 256);
        assert_eq!(c4.cycles, 4 * c1.cycles);
    }

    #[test]
    fn aligned_shapes_reproduce_the_closed_form() {
        // On tile-aligned shapes the per-row accounting reduces to the
        // PR-4 bound tiles · (M + 2A).
        let arr = SystolicArray::new(128);
        let c = arr.gemm(20, 256, 512);
        assert_eq!(c.compute_cycles, 2 * 4 * (20 + 2 * 128));
        assert_eq!(c.cycles, c.compute_cycles, "no memory model configured");
        assert_eq!(c.mem_cycles, 0);
    }

    #[test]
    fn utilization_bounded_by_one() {
        let arr = SystolicArray::new(64);
        for (m, k, n) in [(1, 64, 64), (1000, 64, 64), (20, 650, 2600)] {
            let c = arr.gemm(m, k, n);
            assert!(c.utilization > 0.0 && c.utilization <= 1.0,
                    "util={} for ({m},{k},{n})", c.utilization);
        }
    }

    #[test]
    fn long_stream_amortizes_fill_drain() {
        let arr = SystolicArray::new(128);
        let short = arr.gemm(8, 128, 128);
        let long = arr.gemm(4096, 128, 128);
        assert!(long.utilization > short.utilization * 5.0);
        assert!(long.utilization > 0.9, "util={}", long.utilization);
    }

    #[test]
    fn compaction_speedup_tracks_tile_count() {
        let arr = SystolicArray::new(128);
        // Tile-aligned: H=1536, keep 512 — 12 -> 4 tiles, every cost term
        // scales with the tile count, so the ratio is exactly 3.
        let dense = arr.gemm(20, 1536, 6144);
        let comp = arr.gemm_compacted(20, 1536, 6144, 512);
        assert!((dense.cycles as f64 / comp.cycles as f64 - 3.0).abs() < 1e-9);
        // Paper shape, p=0.5 (Zaremba-medium H=650): halving K halves
        // every K-proportional term and the tile count (6 -> 3), so the
        // speedup is exactly 2.
        let s = arr.compaction_speedup(20, 650, 2600, 0.5);
        assert!((s - 2.0).abs() < 1e-9, "speedup={s}");
    }

    #[test]
    fn compacted_cycles_strictly_monotonic_in_keep() {
        // The acceptance statement: every kept contraction row costs
        // cycles — fill rows are charged per-row, so the naive-schedule
        // cost is *strictly* increasing in the keep count, with and
        // without the memory model.
        for arr in [SystolicArray::new(128), SystolicArray::with_bandwidth(128, 256)] {
            let mut prev = 0u64;
            for keep in 1..=650 {
                let c = arr.gemm_compacted(20, 650, 2600, keep);
                assert!(c.cycles > prev,
                        "cycles not strict at keep={keep}: {} <= {prev}", c.cycles);
                prev = c.cycles;
            }
        }
    }

    #[test]
    fn unstructured_sparsity_gets_no_speedup() {
        let arr = SystolicArray::new(128);
        let dense = arr.gemm(20, 650, 2600);
        let unstructured = arr.gemm_unstructured(20, 650, 2600, 0.5);
        assert_eq!(dense.cycles, unstructured.cycles);
    }

    #[test]
    fn empty_keep_list_costs_zero_stream_tiles() {
        // keep = 0 used to be clamped to a phantom one-row contraction;
        // the empty plan must cost nothing at all.
        let arr = SystolicArray::with_bandwidth(128, 256);
        let c = arr.gemm_compacted(20, 512, 512, 0);
        assert_eq!(c, GemmCost::ZERO);
        assert_eq!(c.stall_cycles(), 0);
        assert_eq!(c.db_cycles(), 0);
    }

    #[test]
    fn singleton_and_full_keep_lists() {
        let arr = SystolicArray::new(128);
        // A single kept unit: one 1-row tile per column strip —
        // tiles_n·K + tiles_k·N + tiles·M = 4·1 + 1·512 + 1·4·20.
        let c1 = arr.gemm_compacted(20, 512, 512, 1);
        assert_eq!(c1.compute_cycles, 4 + 512 + 80);
        // Full keep-list must equal the dense cost exactly.
        let full = arr.gemm_compacted(20, 512, 512, 512);
        assert_eq!(full, arr.gemm(20, 512, 512));
    }

    #[test]
    fn double_buffered_schedule_never_exceeds_naive() {
        let arr = SystolicArray::new(128);
        for (m, k, n) in [(20, 650, 2600), (4, 13, 7), (128, 128, 128), (1, 1, 1),
                          (20, 1500, 6000), (300, 129, 130)] {
            let c = arr.gemm(m, k, n);
            assert!(c.db_compute_cycles <= c.compute_cycles,
                    "db {} > naive {} for ({m},{k},{n})",
                    c.db_compute_cycles, c.compute_cycles);
            // Overlap can hide fills, never the streams themselves.
            let tiles = (k.div_ceil(arr.a) * n.div_ceil(arr.a) * m) as u64;
            assert!(c.db_compute_cycles >= tiles,
                    "db hid stream cycles for ({m},{k},{n})");
        }
    }

    #[test]
    fn memory_stall_term_is_rooflined() {
        // Tiny batch at low bandwidth is memory-bound: total cycles track
        // the traffic, not the compute.
        let slow = SystolicArray::with_bandwidth(128, 4);
        let c = slow.gemm(1, 650, 2600);
        assert!(c.mem_cycles > c.compute_cycles, "should be memory-bound");
        assert_eq!(c.cycles, c.mem_cycles);
        assert_eq!(c.stall_cycles(), c.mem_cycles - c.compute_cycles);
        // Infinite bandwidth: no stalls, compute-bound.
        let fast = SystolicArray::new(128);
        let c = fast.gemm(1, 650, 2600);
        assert_eq!(c.mem_cycles, 0);
        assert_eq!(c.cycles, c.compute_cycles);
    }
}
