//! Systolic-array subsystem: cycle model, tile-schedule execution, and
//! per-phase cycle metering.
//!
//! The paper's hardware claim (§1, §3) is that structured dropout's
//! column-compacted GEMMs are "well-suited to be leveraged in systolic
//! array-based computations" — rigid weight-stationary dataflows can skip
//! whole weight tiles under column compaction, while unstructured sparsity
//! skips nothing. This subsystem turns that claim into a measured result:
//!
//! * [`model`] — the closed-form weight-stationary cycle model (per-row
//!   fill/drain, double-buffered schedule, memory-stall term, compaction
//!   and unstructured-contrast entry points).
//! * [`tiles`] — the streamed tile-schedule kernels the
//!   [`crate::gemm::backend::Systolic`] engine executes GEMMs through,
//!   bit-identical to the `Reference` kernel family by construction.
//! * [`meter`] — the thread-local [`CycleMeter`] that accumulates modeled
//!   cycles per training phase (FP/BP/WG/Other, attributed through
//!   [`crate::train::timing::current_phase`]) for the benches'
//!   cycle-trajectory records.
//!
//! Select the engine with `SDRNN_BACKEND=systolic` (array dimension via
//! `SDRNN_SYSTOLIC_A`, default 128) — see README "GEMM execution
//! backends".

pub mod meter;
pub mod model;
pub mod tiles;

pub use meter::{CycleMeter, CycleTotals, PhaseCycles};
pub use model::{GemmCost, SystolicArray};
