//! Cycle-approximate weight-stationary systolic-array model.
//!
//! The paper argues (§1, §3) that its structured dropout pattern "is also
//! well-suited to be leveraged in systolic array-based computations". This
//! model quantifies that claim: a weight-stationary `A×A` PE array (TPU
//! MXU-like) executing a `[M,K]·[K,N]` GEMM tile-by-tile, where column
//! compaction shrinks the contraction dimension `K → kK` and therefore the
//! number of weight tiles to load and drain.
//!
//! Cycle model per weight tile (standard weight-stationary pipeline):
//!   fill (A cycles) + stream (M cycles) + drain (A cycles)
//! Total = ⌈K/A⌉·⌈N/A⌉ · (M + 2A). This ignores memory stalls — it is an
//! upper bound on achievable utilization, which is the right comparison
//! basis for a *shape* argument (dense vs compacted ratios).

/// Systolic array configuration.
#[derive(Debug, Clone, Copy)]
pub struct SystolicArray {
    /// PE array dimension (A×A). TPU-v2-like default: 128.
    pub a: usize,
}

/// Cost estimate of one GEMM on the array.
#[derive(Debug, Clone, Copy)]
pub struct GemmCost {
    pub cycles: u64,
    /// Useful multiply-accumulates.
    pub macs: u64,
    /// Fraction of peak MACs achieved: `macs / (cycles · A²)`.
    pub utilization: f64,
}

impl SystolicArray {
    pub fn new(a: usize) -> SystolicArray {
        assert!(a > 0);
        SystolicArray { a }
    }

    /// Cost of a dense `[m,k]·[k,n]` GEMM.
    pub fn gemm(&self, m: usize, k: usize, n: usize) -> GemmCost {
        let a = self.a as u64;
        let tiles = (k.div_ceil(self.a) as u64) * (n.div_ceil(self.a) as u64);
        let cycles = tiles * (m as u64 + 2 * a);
        let macs = (m as u64) * (k as u64) * (n as u64);
        GemmCost {
            cycles,
            macs,
            utilization: macs as f64 / (cycles as f64 * (a * a) as f64),
        }
    }

    /// Cost of the same GEMM after column compaction to `keep` of the `k`
    /// contraction rows (the paper's FP input sparsity): fewer weight
    /// tiles, same stream length.
    pub fn gemm_compacted(&self, m: usize, k: usize, n: usize, keep: usize) -> GemmCost {
        assert!(keep <= k);
        self.gemm(m, keep.max(1), n)
    }

    /// Dense-vs-compacted speedup for a keep rate `1-p`.
    pub fn compaction_speedup(&self, m: usize, k: usize, n: usize, p: f32) -> f64 {
        let keep = crate::dropout::mask::keep_count(k, p);
        let dense = self.gemm(m, k, n);
        let comp = self.gemm_compacted(m, k, n, keep);
        dense.cycles as f64 / comp.cycles as f64
    }

    /// Cost under *unstructured* sparsity: random per-element zeros admit
    /// no tile skipping on a rigid systolic dataflow, so the dense cost is
    /// paid regardless (the paper's motivating contrast in §1).
    pub fn gemm_unstructured(&self, m: usize, k: usize, n: usize, _density: f64) -> GemmCost {
        self.gemm(m, k, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_cycles_scale_with_tiles() {
        let arr = SystolicArray::new(128);
        let c1 = arr.gemm(20, 128, 128);
        let c2 = arr.gemm(20, 256, 128);
        assert_eq!(c2.cycles, 2 * c1.cycles);
        let c4 = arr.gemm(20, 256, 256);
        assert_eq!(c4.cycles, 4 * c1.cycles);
    }

    #[test]
    fn utilization_bounded_by_one() {
        let arr = SystolicArray::new(64);
        for (m, k, n) in [(1, 64, 64), (1000, 64, 64), (20, 650, 2600)] {
            let c = arr.gemm(m, k, n);
            assert!(c.utilization > 0.0 && c.utilization <= 1.0,
                    "util={} for ({m},{k},{n})", c.utilization);
        }
    }

    #[test]
    fn long_stream_amortizes_fill_drain() {
        let arr = SystolicArray::new(128);
        let short = arr.gemm(8, 128, 128);
        let long = arr.gemm(4096, 128, 128);
        assert!(long.utilization > short.utilization * 5.0);
        assert!(long.utilization > 0.9, "util={}", long.utilization);
    }

    #[test]
    fn compaction_speedup_tracks_tile_count() {
        let arr = SystolicArray::new(128);
        // H=1500, p=0.65 (Zaremba-large): keep=525. Tiles 12 -> 5.
        let s = arr.compaction_speedup(20, 1500, 6000, 0.65);
        assert!((s - 12.0 / 5.0).abs() < 1e-9, "speedup={s}");
        // p=0.5, H=650 (medium): tiles ceil(650/128)=6 -> ceil(325/128)=3.
        let s = arr.compaction_speedup(20, 650, 2600, 0.5);
        assert!((s - 2.0).abs() < 1e-9);
    }

    #[test]
    fn unstructured_sparsity_gets_no_speedup() {
        let arr = SystolicArray::new(128);
        let dense = arr.gemm(20, 650, 2600);
        let unstructured = arr.gemm_unstructured(20, 650, 2600, 0.5);
        assert_eq!(dense.cycles, unstructured.cycles);
    }

    #[test]
    fn tiny_keep_clamps_to_one_tile_row() {
        let arr = SystolicArray::new(128);
        let c = arr.gemm_compacted(20, 512, 512, 0);
        assert!(c.cycles > 0);
    }
}
