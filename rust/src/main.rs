//! `sdrnn` — command-line launcher for the structured-dropout RNN stack.
//!
//! Subcommands (hand-rolled parser; clap is unavailable offline):
//!
//! ```text
//! sdrnn table1-metrics  [--hidden N] [--vocab N] [--epochs N] [--tokens N]
//! sdrnn table1-speedup  [--reps N]
//! sdrnn table2-metrics  [--hidden N] [--vocab N] [--steps N]
//! sdrnn table2-speedup  [--reps N]
//! sdrnn table3-metrics  [--hidden N] [--vocab N] [--epochs N]
//! sdrnn table3-speedup  [--reps N]
//! sdrnn xla-train       [--model tiny|e2e] [--steps N] [--case I|II|III|IV]
//! sdrnn mask-demo
//! sdrnn info
//! ```

use std::collections::HashMap;

use sdrnn::err;
use sdrnn::util::error::Result;

use sdrnn::coordinator::experiments;
use sdrnn::coordinator::XlaLmTrainer;
use sdrnn::data::batcher::LmBatcher;
use sdrnn::data::corpus::MarkovLmCorpus;
use sdrnn::dropout::plan::{DropoutCase, DropoutConfig, MaskPlanner, Scope};
use sdrnn::optim::sgd::Sgd;
use sdrnn::runtime::ArtifactRegistry;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parse `--key value` pairs after the subcommand.
fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let k = args[i]
            .strip_prefix("--")
            .ok_or_else(|| err!("expected --flag, got '{}'", args[i]))?;
        let v = args
            .get(i + 1)
            .ok_or_else(|| err!("flag --{k} needs a value"))?;
        flags.insert(k.to_string(), v.clone());
        i += 2;
    }
    Ok(flags)
}

fn get<T: std::str::FromStr>(flags: &HashMap<String, String>, k: &str, default: T) -> Result<T> {
    match flags.get(k) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| err!("bad value for --{k}: '{v}'")),
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(args.get(1..).unwrap_or(&[]))?;

    match cmd {
        "table1-metrics" => {
            let rows = experiments::table1_metric_rows(
                get(&flags, "hidden", 64)?,
                get(&flags, "vocab", 2000)?,
                get(&flags, "epochs", 4)?,
                get(&flags, "tokens", 120_000)?,
                get(&flags, "seed", 1u64)?,
            );
            println!("Table 1 (metrics, scaled synthetic PTB):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "table1-speedup" => {
            let rows = experiments::table1_speedup_rows(get(&flags, "reps", 3)?,
                                                        get(&flags, "seed", 1u64)?);
            println!("Table 1 (speedups at paper shapes):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "table2-metrics" => {
            let rows = experiments::table2_metric_rows(
                get(&flags, "hidden", 32)?,
                get(&flags, "vocab", 200)?,
                get(&flags, "steps", 300)?,
                get(&flags, "seed", 1u64)?,
            );
            println!("Table 2 (metrics, synthetic transduction corpus):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "table2-speedup" => {
            let rows = experiments::table2_speedup_rows(get(&flags, "reps", 3)?,
                                                        get(&flags, "seed", 1u64)?);
            println!("Table 2 (speedups at paper shapes):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "table3-metrics" => {
            let rows = experiments::table3_metric_rows(
                get(&flags, "hidden", 24)?,
                get(&flags, "vocab", 600)?,
                get(&flags, "epochs", 3)?,
                get(&flags, "seed", 1u64)?,
            );
            println!("Table 3 (metrics, synthetic CoNLL):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "table3-speedup" => {
            let rows = experiments::table3_speedup_rows(get(&flags, "reps", 3)?,
                                                        get(&flags, "seed", 1u64)?);
            println!("Table 3 (speedups at paper shapes):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "xla-train" => {
            let model = flags.get("model").cloned().unwrap_or_else(|| "tiny".into());
            let steps = get(&flags, "steps", 20)?;
            let case = match flags.get("case").map(String::as_str).unwrap_or("III") {
                "I" => DropoutCase::RandomVarying,
                "II" => DropoutCase::RandomConstant,
                "III" => DropoutCase::StructuredVarying,
                "IV" => DropoutCase::StructuredConstant,
                c => return Err(err!("unknown case '{c}' (use I..IV)")),
            };
            xla_train(&model, steps, case)?;
        }
        "mask-demo" => mask_demo(),
        "info" => info()?,
        _ => {
            println!("{}", HELP);
        }
    }
    Ok(())
}

const HELP: &str = "\
sdrnn — Structured in Space, Randomized in Time (NeurIPS 2021) reproduction

USAGE: sdrnn <subcommand> [--flag value]...

  table1-metrics / table1-speedup    PTB language modelling (Table 1)
  table2-metrics / table2-speedup    IWSLT machine translation (Table 2)
  table3-metrics / table3-speedup    CoNLL-2003 NER (Table 3)
  xla-train   train the AOT-lowered XLA LM artifact from Rust
  mask-demo   print the Fig. 1 mask taxonomy
  info        PJRT platform + artifact inventory

Benches regenerate the full tables: `cargo bench --bench table1_ptb` etc.
Examples: `cargo run --release --example e2e_lm_ptb` (end-to-end driver).";

/// Train the lowered artifact for a few steps; prints the loss curve.
fn xla_train(model: &str, steps: usize, case: DropoutCase) -> Result<()> {
    let mut reg = ArtifactRegistry::open(&ArtifactRegistry::default_dir())?;
    println!("platform: {}", reg.platform());
    let dropout = DropoutConfig { case, scope: Scope::NrRh, p_nr: 0.3, p_rh: 0.3 };
    let sgd = Sgd::new(1.0, 5.0, usize::MAX, 1.0);
    let mut trainer = XlaLmTrainer::new(&mut reg, model, dropout, sgd, 7)?;
    let m = trainer.manifest.clone();
    println!("model '{model}': V={} H={} L={} B={} T={} ({} params)",
             m.vocab, m.hidden, m.layers, m.batch, m.seq_len, m.total_params());

    let corpus = MarkovLmCorpus::new(m.vocab, 5, 0.85, 11);
    let stream = corpus.generate(m.batch * (m.seq_len * steps + 1) + m.batch, 13);
    let mut batcher = LmBatcher::new(&stream, m.batch, m.seq_len);
    for step in 0..steps {
        let win = match batcher.next_window() {
            Some(w) => w,
            None => {
                batcher.reset();
                batcher.next_window().unwrap()
            }
        };
        let loss = trainer.train_step(&win)?;
        println!("step {step:>4}  loss {loss:.4}  ppl {:.1}", loss.exp());
    }
    Ok(())
}

/// Print the four Fig. 1 cases as ASCII mask matrices.
fn mask_demo() {
    let (t, b, h) = (4, 6, 16);
    println!("Fig. 1 — dropout mask taxonomy (B={b}, H={h}, {t} time steps; #=dropped)\n");
    for case in [
        DropoutCase::RandomVarying,
        DropoutCase::RandomConstant,
        DropoutCase::StructuredVarying,
        DropoutCase::StructuredConstant,
    ] {
        println!("{}:", case.label());
        let cfg = DropoutConfig { case, scope: Scope::Nr, p_nr: 0.5, p_rh: 0.0 };
        let mut planner = MaskPlanner::new(cfg, 42);
        let plan = planner.plan(t, b, h, 1);
        for (ti, step) in plan.steps.iter().enumerate() {
            let dense = step.mx[0].to_dense(b);
            print!("  t={ti}: ");
            for r in 0..b {
                let row: String = (0..h)
                    .map(|c| if dense[r * h + c] == 0.0 { '#' } else { '.' })
                    .collect();
                print!("{row}  ");
            }
            println!();
        }
        println!();
    }
}

/// Show PJRT + artifact inventory.
fn info() -> Result<()> {
    let dir = ArtifactRegistry::default_dir();
    println!("artifacts dir: {}", dir.display());
    if !dir.join("manifest.json").exists() {
        println!("artifacts not built — run `make artifacts`");
        return Ok(());
    }
    let reg = ArtifactRegistry::open(&dir)?;
    println!("PJRT platform: {}", reg.platform());
    for (name, m) in &reg.manifest.models {
        println!("  model '{name}': V={} H={} L={} B={} T={} -> {} / {}",
                 m.vocab, m.hidden, m.layers, m.batch, m.seq_len,
                 m.step_artifact, m.eval_artifact);
    }
    if let Some(c) = &reg.manifest.cell {
        println!("  cell: B={} Dx={} H={} -> {}", c.batch, c.dx, c.hidden, c.artifact);
    }
    Ok(())
}
