//! `sdrnn` — command-line launcher for the structured-dropout RNN stack.
//!
//! Subcommands (hand-rolled parser; clap is unavailable offline):
//!
//! ```text
//! sdrnn table1-metrics  [--hidden N] [--vocab N] [--epochs N] [--tokens N] [ckpt flags]
//! sdrnn table1-speedup  [--reps N]
//! sdrnn table2-metrics  [--hidden N] [--vocab N] [--steps N] [ckpt flags]
//! sdrnn table2-speedup  [--reps N]
//! sdrnn table3-metrics  [--hidden N] [--vocab N] [--epochs N] [ckpt flags]
//! sdrnn table3-speedup  [--reps N]
//! sdrnn supervise       [--hidden N] [--vocab N] [--epochs N] [--tokens N]
//!                       [--retries N] [--max-windows N] [ckpt flags]
//! sdrnn submit          --out FILE [--task lm|nmt|ner] [spec flags] [run flags]
//! sdrnn serve           --jobs FILE [--pools P] [--telemetry D] [--ckpt-root D]
//!                       [--retries N] [--resume 0|1] [run flags]
//! sdrnn xla-train       [--model tiny|e2e] [--steps N] [--case I|II|III|IV]
//! sdrnn mask-demo
//! sdrnn info
//!
//! ckpt flags: [--ckpt-dir D] [--every N] [--resume 0|1] [--faults SPEC]
//!             [--timeout-ms N]
//! run flags:  ckpt flags + [--backend E] [--threads N] [--systolic-a N]
//! ```

use std::collections::{HashMap, HashSet};
use std::io::Write;
use std::path::PathBuf;

use sdrnn::err;
use sdrnn::util::error::Result;

use sdrnn::coordinator::experiments;
use sdrnn::coordinator::logger::JobLogs;
use sdrnn::coordinator::XlaLmTrainer;
use sdrnn::coordinator::{parse_pools, Service, ServiceConfig};
use sdrnn::coordinator::{run_lm_supervised, SupervisorConfig};
use sdrnn::data::batcher::LmBatcher;
use sdrnn::data::corpus::MarkovLmCorpus;
use sdrnn::dropout::plan::{DropoutCase, DropoutConfig, MaskPlanner, Scope};
use sdrnn::optim::sgd::Sgd;
use sdrnn::runtime::ArtifactRegistry;
use sdrnn::train::checkpoint::prune;
use sdrnn::train::lm::LmTrainConfig;
use sdrnn::train::{JobSpec, RunPolicy};
use sdrnn::util::config::RunConfig;
use sdrnn::util::json::Json;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parse `--key value` pairs after the subcommand.
fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let k = args[i]
            .strip_prefix("--")
            .ok_or_else(|| err!("expected --flag, got '{}'", args[i]))?;
        let v = args
            .get(i + 1)
            .ok_or_else(|| err!("flag --{k} needs a value"))?;
        flags.insert(k.to_string(), v.clone());
        i += 2;
    }
    Ok(flags)
}

fn get<T: std::str::FromStr>(flags: &HashMap<String, String>, k: &str, default: T) -> Result<T> {
    match flags.get(k) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| err!("bad value for --{k}: '{v}'")),
    }
}

/// Build a [`RunPolicy`] from the shared ckpt flags through the unified
/// [`RunConfig`] layering (env under flags). `--resume 0` (the default)
/// clears any stale snapshots so the run truly starts fresh.
fn policy_from_flags(flags: &HashMap<String, String>) -> Result<(RunPolicy, bool)> {
    let rc = RunConfig::from_env().overlay(&RunConfig::from_flags(flags)?);
    let (policy, resume) = rc.policy()?;
    if !resume {
        if let Some(dir) = &policy.ckpt_dir {
            prune(dir, 0);
        }
    }
    Ok((policy, resume))
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(args.get(1..).unwrap_or(&[]))?;

    match cmd {
        "table1-metrics" => {
            let (policy, resume) = policy_from_flags(&flags)?;
            let rows = experiments::table1_metric_rows_ckpt(
                get(&flags, "hidden", 64)?,
                get(&flags, "vocab", 2000)?,
                get(&flags, "epochs", 4)?,
                get(&flags, "tokens", 120_000)?,
                get(&flags, "seed", 1u64)?,
                &policy,
                resume,
            )?;
            println!("Table 1 (metrics, scaled synthetic PTB):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "table1-speedup" => {
            let rows = experiments::table1_speedup_rows(get(&flags, "reps", 3)?,
                                                        get(&flags, "seed", 1u64)?);
            println!("Table 1 (speedups at paper shapes):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "table2-metrics" => {
            let (policy, resume) = policy_from_flags(&flags)?;
            let rows = experiments::table2_metric_rows_ckpt(
                get(&flags, "hidden", 32)?,
                get(&flags, "vocab", 200)?,
                get(&flags, "steps", 300)?,
                get(&flags, "seed", 1u64)?,
                &policy,
                resume,
            )?;
            println!("Table 2 (metrics, synthetic transduction corpus):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "table2-speedup" => {
            let rows = experiments::table2_speedup_rows(get(&flags, "reps", 3)?,
                                                        get(&flags, "seed", 1u64)?);
            println!("Table 2 (speedups at paper shapes):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "table3-metrics" => {
            let (policy, resume) = policy_from_flags(&flags)?;
            let rows = experiments::table3_metric_rows_ckpt(
                get(&flags, "hidden", 24)?,
                get(&flags, "vocab", 600)?,
                get(&flags, "epochs", 3)?,
                get(&flags, "seed", 1u64)?,
                &policy,
                resume,
            )?;
            println!("Table 3 (metrics, synthetic CoNLL):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "table3-speedup" => {
            let rows = experiments::table3_speedup_rows(get(&flags, "reps", 3)?,
                                                        get(&flags, "seed", 1u64)?);
            println!("Table 3 (speedups at paper shapes):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "xla-train" => {
            let model = flags.get("model").cloned().unwrap_or_else(|| "tiny".into());
            let steps = get(&flags, "steps", 20)?;
            let case = match flags.get("case").map(String::as_str).unwrap_or("III") {
                "I" => DropoutCase::RandomVarying,
                "II" => DropoutCase::RandomConstant,
                "III" => DropoutCase::StructuredVarying,
                "IV" => DropoutCase::StructuredConstant,
                c => return Err(err!("unknown case '{c}' (use I..IV)")),
            };
            xla_train(&model, steps, case)?;
        }
        "supervise" => supervise_cmd(&flags)?,
        "submit" => submit_cmd(&flags)?,
        "serve" => serve_cmd(&flags)?,
        "mask-demo" => mask_demo(),
        "info" => info()?,
        _ => {
            println!("{}", HELP);
        }
    }
    Ok(())
}

const HELP: &str = "\
sdrnn — Structured in Space, Randomized in Time (NeurIPS 2021) reproduction

USAGE: sdrnn <subcommand> [--flag value]...

  table1-metrics / table1-speedup    PTB language modelling (Table 1)
  table2-metrics / table2-speedup    IWSLT machine translation (Table 2)
  table3-metrics / table3-speedup    CoNLL-2003 NER (Table 3)
  supervise   fault-tolerant LM run: checkpoints, retries, resume
  submit      append a JobSpec JSON line to a jobs file
  serve       run a jobs file through the experiment service
  xla-train   train the AOT-lowered XLA LM artifact from Rust
  mask-demo   print the Fig. 1 mask taxonomy
  info        PJRT platform + artifact inventory

Fault-tolerance flags (metric tables + supervise + serve):
  --ckpt-dir D     snapshot directory (enables checkpointing)
  --every N        snapshot every N windows (default 25)
  --resume 0|1     1 = continue from the newest loadable snapshot;
                   0 = fresh run (stale snapshots are cleared)
  --faults SPEC    deterministic fault schedule (SDRNN_FAULTS grammar)
  --timeout-ms N   per-window watchdog limit

Experiment service:
  submit --out jobs.jsonl --task lm|nmt|ner [--hidden N] [--vocab N]
         [--epochs N] [--steps N] [--tokens N] [--seed N] [--keep F]
         [--variant none|nr-random|nr-st|nr-rh-st] [--batch N] [--seq-len N]
         [--max-windows N] [--priority N] [--pool NAME]
         [--backend E] [--threads N] [run flags -> per-job overrides]
  serve  --jobs jobs.jsonl [--pools engine:threads:workers,...]
         [--telemetry DIR] [--ckpt-root DIR] [--every N] [--retries N]
         [--resume 0|1] [--backend E] [--threads N]
         job ids are jobs-file line numbers; --resume 1 skips jobs whose
         index record says done and resumes the rest from checkpoints

Benches regenerate the full tables: `cargo bench --bench table1_ptb` etc.
Examples: `cargo run --release --example e2e_lm_ptb` (end-to-end driver).";

/// Supervised LM run on the synthetic PTB: periodic checkpoints, panic
/// capture, retry with backoff, engine degradation, and resume from the
/// newest loadable snapshot. Exits nonzero when every attempt fails —
/// the CI crash-recovery smoke drives this subcommand with an injected
/// kill and then resumes it.
fn supervise_cmd(flags: &HashMap<String, String>) -> Result<()> {
    let task = flags.get("task").map(String::as_str).unwrap_or("lm");
    if task != "lm" {
        return Err(err!("supervise: unknown task '{task}' (only 'lm' is wired up)"));
    }
    let hidden = get(flags, "hidden", 16)?;
    let vocab = get(flags, "vocab", 60)?;
    let seed = get(flags, "seed", 1u64)?;
    let (policy, resume) = policy_from_flags(flags)?;

    let corpus = MarkovLmCorpus::new(vocab, 5, 0.85, seed);
    let (tr, va, te) = corpus.splits(get(flags, "tokens", 40_000)?);
    let mut cfg = LmTrainConfig::zaremba_medium(hidden, vocab, DropoutConfig::nr_st(0.5));
    cfg.epochs = get(flags, "epochs", 2)?;
    cfg.seed = seed;
    let cap = get(flags, "max-windows", 0usize)?;
    if cap > 0 {
        cfg.max_windows_per_epoch = Some(cap);
    }

    let sup = SupervisorConfig::new(get(flags, "retries", 3)?);
    let ckpt_desc = match &policy.ckpt_dir {
        Some(d) => d.display().to_string(),
        None => "(off)".to_string(),
    };
    println!("supervise: task=lm hidden={hidden} vocab={vocab} epochs={} resume={resume} \
              ckpt={ckpt_desc}",
             cfg.epochs);
    let rep = run_lm_supervised(&cfg, &tr, &va, &te, &policy, &sup);
    for a in &rep.attempts {
        println!("  attempt {} [{}]: {} (backoff {:?})",
                 a.attempt, a.engine, a.outcome, a.backoff);
    }
    match rep.result {
        Some(res) => {
            println!("supervised run ok after {} retries (final engine '{}')",
                     rep.retries(), rep.final_engine);
            println!("  test_ppl={:.3} params_fnv={:016x} mask_rng={:016x}",
                     res.test_ppl, res.final_params_fnv, res.final_mask_rng);
            println!("  checkpoints written={} overhead={:?} resumed={}",
                     res.ckpt_written, res.ckpt_overhead, res.resumed);
            Ok(())
        }
        None => Err(err!("supervised run failed after {} attempts", rep.attempts.len())),
    }
}

/// Build a [`JobSpec`] from the submit flags and append it as one JSON
/// line to the jobs file (`--out`). The service reads this file back with
/// `serve --jobs`.
fn submit_cmd(flags: &HashMap<String, String>) -> Result<()> {
    let out = flags
        .get("out")
        .ok_or_else(|| err!("submit: --out FILE is required"))?;
    let task = flags.get("task").map(String::as_str).unwrap_or("lm");
    if !matches!(task, "lm" | "nmt" | "ner") {
        return Err(err!("submit: unknown task '{task}' (lm|nmt|ner)"));
    }
    let mut spec = JobSpec::quick(task);
    spec.hidden = get(flags, "hidden", spec.hidden)?;
    spec.vocab = get(flags, "vocab", spec.vocab)?;
    spec.epochs = get(flags, "epochs", spec.epochs)?;
    spec.steps = get(flags, "steps", spec.steps)?;
    spec.tokens = get(flags, "tokens", spec.tokens)?;
    spec.seed = get(flags, "seed", spec.seed)?;
    spec.keep = get(flags, "keep", spec.keep)?;
    if let Some(v) = flags.get("variant") {
        spec.variant = v.clone();
    }
    spec.batch = get(flags, "batch", spec.batch)?;
    spec.seq_len = get(flags, "seq-len", spec.seq_len)?;
    if flags.contains_key("max-windows") {
        let n = get(flags, "max-windows", 0usize)?;
        spec.max_windows = if n > 0 { Some(n) } else { None };
    }
    spec.priority = get(flags, "priority", spec.priority)?;
    spec.pool = flags.get("pool").cloned();
    // Per-job run-knob overrides ride along in the spec's `run` layer.
    spec.run = RunConfig::from_flags(flags)?;
    // Round-trip through the JSON schema to validate variant/keep eagerly —
    // a bad submission should fail here, not inside a worker.
    let spec = JobSpec::from_json(&spec.to_json())?;

    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(out)
        .map_err(|e| err!("submit: opening {out}: {e}"))?;
    writeln!(f, "{}", spec.to_json()).map_err(|e| err!("submit: writing {out}: {e}"))?;
    println!("submit: queued {} job (keep={}, variant={}) -> {out}",
             spec.task, spec.keep, spec.variant);
    Ok(())
}

/// Run a jobs file through the multi-tenant experiment service. Job ids
/// are jobs-file line numbers, so `--resume 1` can skip jobs whose index
/// record already says `done` and resume the rest from their
/// `--ckpt-root` checkpoints. Exits nonzero when any job fails.
fn serve_cmd(flags: &HashMap<String, String>) -> Result<()> {
    let jobs_path = flags
        .get("jobs")
        .ok_or_else(|| err!("serve: --jobs FILE is required"))?;
    let pools = parse_pools(flags.get("pools").map(String::as_str).unwrap_or("reference:1:2"))?;
    let base = RunConfig::from_env().overlay(&RunConfig::from_flags(flags)?);
    let resume = base.resume.unwrap_or(false);

    let mut cfg = ServiceConfig::new(pools);
    cfg.telemetry = flags.get("telemetry").map(PathBuf::from);
    cfg.ckpt_root = flags.get("ckpt-root").map(PathBuf::from);
    cfg.sup = SupervisorConfig::new(get(flags, "retries", 2)?);
    cfg.base = base;

    let text = std::fs::read_to_string(jobs_path)
        .map_err(|e| err!("serve: reading {jobs_path}: {e}"))?;
    let mut specs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .map_err(|e| err!("serve: {jobs_path} line {}: {e}", lineno + 1))?;
        specs.push(JobSpec::from_json(&j)
            .map_err(|e| err!("serve: {jobs_path} line {}: {e}", lineno + 1))?);
    }
    if specs.is_empty() {
        return Err(err!("serve: {jobs_path} holds no jobs"));
    }

    // On resume, the previous run's live index tells us which ids already
    // reached `done`; everything else is resubmitted with resume enabled.
    let done: HashSet<u64> = match (&cfg.telemetry, resume) {
        (Some(dir), true) => JobLogs::new(dir)
            .read_index()
            .map(|idx| {
                idx.records
                    .iter()
                    .filter(|r| r.get("state").and_then(Json::as_str) == Some("done"))
                    .filter_map(|r| r.get("id").and_then(Json::as_usize))
                    .map(|id| id as u64)
                    .collect()
            })
            .unwrap_or_default(),
        _ => HashSet::new(),
    };

    let total = specs.len();
    let svc = Service::start(cfg)?;
    let mut skipped = 0usize;
    for (i, mut spec) in specs.into_iter().enumerate() {
        let id = i as u64;
        if done.contains(&id) {
            println!("job {id}: already done, skipped");
            skipped += 1;
            continue;
        }
        if resume {
            spec.run.resume = Some(true);
        }
        svc.submit_as(id, spec)?;
    }
    let report = svc.drain()?;

    let mut outs = report.outcomes.clone();
    outs.sort_by_key(|o| o.id);
    for o in &outs {
        println!("job {} [{} on {}] {}: {} attempts={} engine={} windows={} \
                  resumed={} wait={:.1}ms",
                 o.id, o.task, o.pool,
                 if o.ok { "done" } else { "failed" },
                 o.outcome, o.attempts, o.final_engine, o.windows, o.resumed,
                 o.queue_wait.as_secs_f64() * 1e3);
    }
    println!("serve: {total} jobs — {} done, {} failed, {skipped} skipped; \
              {:.1} jobs/s; queue wait p50 {:.1}ms p99 {:.1}ms; steals {}; \
              cache {}/{} hits",
             report.completed(), report.failed(),
             report.throughput_jobs_per_s(),
             report.queue_wait_percentile(50.0).as_secs_f64() * 1e3,
             report.queue_wait_percentile(99.0).as_secs_f64() * 1e3,
             report.total_steals(),
             report.cache.hits, report.cache.hits + report.cache.misses);
    if report.failed() > 0 {
        return Err(err!("serve: {} job(s) failed", report.failed()));
    }
    Ok(())
}

/// Train the lowered artifact for a few steps; prints the loss curve.
fn xla_train(model: &str, steps: usize, case: DropoutCase) -> Result<()> {
    let mut reg = ArtifactRegistry::open(&ArtifactRegistry::default_dir())?;
    println!("platform: {}", reg.platform());
    let dropout = DropoutConfig { case, scope: Scope::NrRh, p_nr: 0.3, p_rh: 0.3 };
    let sgd = Sgd::new(1.0, 5.0, usize::MAX, 1.0);
    let mut trainer = XlaLmTrainer::new(&mut reg, model, dropout, sgd, 7)?;
    let m = trainer.manifest.clone();
    println!("model '{model}': V={} H={} L={} B={} T={} ({} params)",
             m.vocab, m.hidden, m.layers, m.batch, m.seq_len, m.total_params());

    let corpus = MarkovLmCorpus::new(m.vocab, 5, 0.85, 11);
    let stream = corpus.generate(m.batch * (m.seq_len * steps + 1) + m.batch, 13);
    let mut batcher = LmBatcher::new(&stream, m.batch, m.seq_len);
    for step in 0..steps {
        let win = match batcher.next_window() {
            Some(w) => w,
            None => {
                batcher.reset();
                batcher.next_window().unwrap()
            }
        };
        let loss = trainer.train_step(&win)?;
        println!("step {step:>4}  loss {loss:.4}  ppl {:.1}", loss.exp());
    }
    Ok(())
}

/// Print the four Fig. 1 cases as ASCII mask matrices.
fn mask_demo() {
    let (t, b, h) = (4, 6, 16);
    println!("Fig. 1 — dropout mask taxonomy (B={b}, H={h}, {t} time steps; #=dropped)\n");
    for case in [
        DropoutCase::RandomVarying,
        DropoutCase::RandomConstant,
        DropoutCase::StructuredVarying,
        DropoutCase::StructuredConstant,
    ] {
        println!("{}:", case.label());
        let cfg = DropoutConfig { case, scope: Scope::Nr, p_nr: 0.5, p_rh: 0.0 };
        let mut planner = MaskPlanner::new(cfg, 42);
        let plan = planner.plan(t, b, h, 1);
        for (ti, step) in plan.steps.iter().enumerate() {
            let dense = step.mx[0].to_dense(b);
            print!("  t={ti}: ");
            for r in 0..b {
                let row: String = (0..h)
                    .map(|c| if dense[r * h + c] == 0.0 { '#' } else { '.' })
                    .collect();
                print!("{row}  ");
            }
            println!();
        }
        println!();
    }
}

/// Show PJRT + artifact inventory.
fn info() -> Result<()> {
    let dir = ArtifactRegistry::default_dir();
    println!("artifacts dir: {}", dir.display());
    if !dir.join("manifest.json").exists() {
        println!("artifacts not built — run `make artifacts`");
        return Ok(());
    }
    let reg = ArtifactRegistry::open(&dir)?;
    println!("PJRT platform: {}", reg.platform());
    for (name, m) in &reg.manifest.models {
        println!("  model '{name}': V={} H={} L={} B={} T={} -> {} / {}",
                 m.vocab, m.hidden, m.layers, m.batch, m.seq_len,
                 m.step_artifact, m.eval_artifact);
    }
    if let Some(c) = &reg.manifest.cell {
        println!("  cell: B={} Dx={} H={} -> {}", c.batch, c.dx, c.hidden, c.artifact);
    }
    Ok(())
}
