//! `sdrnn` — command-line launcher for the structured-dropout RNN stack.
//!
//! Subcommands (hand-rolled parser; clap is unavailable offline):
//!
//! ```text
//! sdrnn table1-metrics  [--hidden N] [--vocab N] [--epochs N] [--tokens N] [ckpt flags]
//! sdrnn table1-speedup  [--reps N]
//! sdrnn table2-metrics  [--hidden N] [--vocab N] [--steps N] [ckpt flags]
//! sdrnn table2-speedup  [--reps N]
//! sdrnn table3-metrics  [--hidden N] [--vocab N] [--epochs N] [ckpt flags]
//! sdrnn table3-speedup  [--reps N]
//! sdrnn supervise       [--hidden N] [--vocab N] [--epochs N] [--tokens N]
//!                       [--retries N] [--max-windows N] [ckpt flags]
//! sdrnn submit          --jobs FILE | --connect ADDR  [--task lm|nmt|ner]
//!                       [spec flags] [run flags]
//! sdrnn serve           --jobs FILE [--listen ADDR] [--pools P] [--telemetry D]
//!                       [--ckpt-root D] [--retries N] [--resume 0|1] [run flags]
//! sdrnn status          --connect ADDR
//! sdrnn watch           --connect ADDR [--from N] [--count N]
//! sdrnn drain           --connect ADDR
//! sdrnn xla-train       [--model tiny|e2e] [--steps N] [--case I|II|III|IV]
//! sdrnn mask-demo
//! sdrnn info
//!
//! ckpt flags: [--ckpt-dir D] [--every N] [--resume 0|1] [--faults SPEC]
//!             [--timeout-ms N]
//! run flags:  ckpt flags + [--backend E] [--threads N] [--systolic-a N]
//! ```
//!
//! All flag parsing goes through the shared [`Flags`] layer
//! (`util::cli`): `--key value` and `--key=value` both work, and the
//! pre-unification spellings (`--out`, `--ckpt`, `--timeout`) keep
//! working as aliases. Flags a subcommand does not read are rejected
//! with the valid set (see `validate_flags`).

use std::collections::HashSet;
use std::io::Write;
use std::path::PathBuf;

use sdrnn::err;
use sdrnn::util::error::Result;

use sdrnn::coordinator::experiments;
use sdrnn::coordinator::logger::{runs_dir, JobLogs};
use sdrnn::coordinator::XlaLmTrainer;
use sdrnn::coordinator::{parse_pools, Service, ServiceConfig, ServiceReport};
use sdrnn::coordinator::{proto, Request, Response, Server, ServerConfig};
use sdrnn::coordinator::{run_lm_supervised, SupervisorConfig};
use sdrnn::data::batcher::LmBatcher;
use sdrnn::data::corpus::MarkovLmCorpus;
use sdrnn::dropout::plan::{DropoutCase, DropoutConfig, MaskPlanner, Scope};
use sdrnn::optim::sgd::Sgd;
use sdrnn::runtime::ArtifactRegistry;
use sdrnn::train::lm::LmTrainConfig;
use sdrnn::train::JobSpec;
use sdrnn::util::cli::{Flags, CKPT_FLAGS, ENGINE_FLAGS, SPEC_FLAGS};
use sdrnn::util::json::Json;
use sdrnn::util::net::Client;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Per-subcommand flag allow-lists. Misspelled flags used to be
/// silently ignored (`--tiemout-ms` ran with the default watchdog);
/// now every subcommand rejects keys it does not read, listing the
/// valid set. Unknown subcommands still fall through to HELP without
/// flag validation.
fn validate_flags(cmd: &str, flags: &Flags) -> Result<()> {
    const METRICS: &[&str] = &["hidden", "vocab", "epochs", "steps", "tokens", "seed"];
    const SPEEDUP: &[&str] = &["reps", "seed"];
    const SUPERVISE: &[&str] = &["task", "hidden", "vocab", "epochs", "tokens", "seed",
                                 "retries", "max-windows"];
    const SUBMIT: &[&str] = &["jobs", "connect"];
    const SERVE: &[&str] = &["jobs", "listen", "pools", "telemetry", "ckpt-root",
                             "retries", "addr-file", "max-queue", "retry-after-ms",
                             "allow-remote"];
    const CONNECT: &[&str] = &["connect"];
    const WATCH: &[&str] = &["connect", "from", "count"];
    const XLA: &[&str] = &["model", "steps", "case"];

    let groups: &[&[&str]] = match cmd {
        "table1-metrics" | "table2-metrics" | "table3-metrics" => {
            &[METRICS, CKPT_FLAGS, ENGINE_FLAGS]
        }
        "table1-speedup" | "table2-speedup" | "table3-speedup" => &[SPEEDUP],
        "supervise" => &[SUPERVISE, CKPT_FLAGS, ENGINE_FLAGS],
        "submit" => &[SUBMIT, SPEC_FLAGS, CKPT_FLAGS, ENGINE_FLAGS],
        "serve" => &[SERVE, CKPT_FLAGS, ENGINE_FLAGS],
        "status" | "drain" => &[CONNECT],
        "watch" => &[WATCH],
        "xla-train" => &[XLA],
        "mask-demo" | "info" => &[],
        _ => return Ok(()),
    };
    flags.expect_known(cmd, groups)
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = Flags::parse(args.get(1..).unwrap_or(&[]))?;
    validate_flags(cmd, &flags)?;

    match cmd {
        "table1-metrics" => {
            let (policy, resume) = flags.policy()?;
            let rows = experiments::table1_metric_rows_ckpt(
                flags.get("hidden", 64)?,
                flags.get("vocab", 2000)?,
                flags.get("epochs", 4)?,
                flags.get("tokens", 120_000)?,
                flags.get("seed", 1u64)?,
                &policy,
                resume,
            )?;
            println!("Table 1 (metrics, scaled synthetic PTB):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "table1-speedup" => {
            let rows = experiments::table1_speedup_rows(flags.get("reps", 3)?,
                                                        flags.get("seed", 1u64)?);
            println!("Table 1 (speedups at paper shapes):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "table2-metrics" => {
            let (policy, resume) = flags.policy()?;
            let rows = experiments::table2_metric_rows_ckpt(
                flags.get("hidden", 32)?,
                flags.get("vocab", 200)?,
                flags.get("steps", 300)?,
                flags.get("seed", 1u64)?,
                &policy,
                resume,
            )?;
            println!("Table 2 (metrics, synthetic transduction corpus):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "table2-speedup" => {
            let rows = experiments::table2_speedup_rows(flags.get("reps", 3)?,
                                                        flags.get("seed", 1u64)?);
            println!("Table 2 (speedups at paper shapes):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "table3-metrics" => {
            let (policy, resume) = flags.policy()?;
            let rows = experiments::table3_metric_rows_ckpt(
                flags.get("hidden", 24)?,
                flags.get("vocab", 600)?,
                flags.get("epochs", 3)?,
                flags.get("seed", 1u64)?,
                &policy,
                resume,
            )?;
            println!("Table 3 (metrics, synthetic CoNLL):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "table3-speedup" => {
            let rows = experiments::table3_speedup_rows(flags.get("reps", 3)?,
                                                        flags.get("seed", 1u64)?);
            println!("Table 3 (speedups at paper shapes):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "xla-train" => {
            let model = flags.str_or("model", "tiny").to_string();
            let steps = flags.get("steps", 20)?;
            let case = match flags.str_or("case", "III") {
                "I" => DropoutCase::RandomVarying,
                "II" => DropoutCase::RandomConstant,
                "III" => DropoutCase::StructuredVarying,
                "IV" => DropoutCase::StructuredConstant,
                c => return Err(err!("unknown case '{c}' (use I..IV)")),
            };
            xla_train(&model, steps, case)?;
        }
        "supervise" => supervise_cmd(&flags)?,
        "submit" => submit_cmd(&flags)?,
        "serve" => serve_cmd(&flags)?,
        "status" => status_cmd(&flags)?,
        "watch" => watch_cmd(&flags)?,
        "drain" => drain_cmd(&flags)?,
        "mask-demo" => mask_demo(),
        "info" => info()?,
        _ => {
            println!("{}", HELP);
        }
    }
    Ok(())
}

const HELP: &str = "\
sdrnn — Structured in Space, Randomized in Time (NeurIPS 2021) reproduction

USAGE: sdrnn <subcommand> [--flag value | --flag=value]...

  table1-metrics / table1-speedup    PTB language modelling (Table 1)
  table2-metrics / table2-speedup    IWSLT machine translation (Table 2)
  table3-metrics / table3-speedup    CoNLL-2003 NER (Table 3)
  supervise   fault-tolerant LM run: checkpoints, retries, resume
  submit      queue a JobSpec: to a jobs file, or over TCP (--connect)
  serve       run the experiment service: batch jobs file and/or TCP front
              end (--listen)
  status      one-shot service counters over TCP
  watch       stream job state transitions over TCP until terminal
  drain       close the queue over TCP and wait for the final report
  xla-train   train the AOT-lowered XLA LM artifact from Rust
  mask-demo   print the Fig. 1 mask taxonomy
  info        PJRT platform + artifact inventory

Fault-tolerance flags (metric tables + supervise + serve):
  --ckpt-dir D     snapshot directory (enables checkpointing)
  --every N        snapshot every N windows (default 25)
  --resume 0|1     1 = continue from the newest loadable snapshot;
                   0 = fresh run (stale snapshots are cleared)
  --faults SPEC    deterministic fault schedule (SDRNN_FAULTS grammar)
  --timeout-ms N   per-window watchdog limit

Experiment service (wire protocol v1: newline-delimited JSON frames,
versioned `v` field; see README 'Experiment service'):
  submit --jobs jobs.jsonl | --connect HOST:PORT
         [--task lm|nmt|ner] [--hidden N] [--vocab N] [--epochs N]
         [--steps N] [--tokens N] [--seed N] [--keep F]
         [--variant none|nr-random|nr-st|nr-rh-st] [--batch N] [--seq-len N]
         [--max-windows N] [--priority N] [--pool NAME]
         [--backend E] [--threads N] [run flags -> per-job overrides]
         (--out is an alias for --jobs; --connect retries on busy frames)
  serve  --jobs jobs.jsonl [--pools engine:threads:workers,...]
         [--telemetry DIR] [--ckpt-root DIR] [--every N] [--retries N]
         [--resume 0|1] [--backend E] [--threads N]
         [--listen HOST:PORT] [--addr-file PATH] [--max-queue N]
         [--retry-after-ms N] [--allow-remote 0|1]
         batch mode drains the jobs file and exits; --listen also accepts
         TCP submissions (journalled to --jobs) until a client drains it.
         Job ids are jobs-file line numbers; --resume 1 skips jobs whose
         index record says done and resumes the rest from checkpoints.
  status --connect HOST:PORT
  watch  --connect HOST:PORT [--from SEQ] [--count N]
         streams index records; exits nonzero if any watched job failed
  drain  --connect HOST:PORT
         closes the queue, waits for the backlog, prints the final report

Benches regenerate the full tables: `cargo bench --bench table1_ptb` etc.
Examples: `cargo run --release --example e2e_lm_ptb` (end-to-end driver).";

/// Supervised LM run on the synthetic PTB: periodic checkpoints, panic
/// capture, retry with backoff, engine degradation, and resume from the
/// newest loadable snapshot. Exits nonzero when every attempt fails —
/// the CI crash-recovery smoke drives this subcommand with an injected
/// kill and then resumes it.
fn supervise_cmd(flags: &Flags) -> Result<()> {
    let task = flags.str_or("task", "lm");
    if task != "lm" {
        return Err(err!("supervise: unknown task '{task}' (only 'lm' is wired up)"));
    }
    let hidden = flags.get("hidden", 16)?;
    let vocab = flags.get("vocab", 60)?;
    let seed = flags.get("seed", 1u64)?;
    let (policy, resume) = flags.policy()?;

    let corpus = MarkovLmCorpus::new(vocab, 5, 0.85, seed);
    let (tr, va, te) = corpus.splits(flags.get("tokens", 40_000)?);
    let mut cfg = LmTrainConfig::zaremba_medium(hidden, vocab, DropoutConfig::nr_st(0.5));
    cfg.epochs = flags.get("epochs", 2)?;
    cfg.seed = seed;
    let cap = flags.get("max-windows", 0usize)?;
    if cap > 0 {
        cfg.max_windows_per_epoch = Some(cap);
    }

    let sup = SupervisorConfig::new(flags.get("retries", 3)?);
    let ckpt_desc = match &policy.ckpt_dir {
        Some(d) => d.display().to_string(),
        None => "(off)".to_string(),
    };
    println!("supervise: task=lm hidden={hidden} vocab={vocab} epochs={} resume={resume} \
              ckpt={ckpt_desc}",
             cfg.epochs);
    let rep = run_lm_supervised(&cfg, &tr, &va, &te, &policy, &sup);
    for a in &rep.attempts {
        println!("  attempt {} [{}]: {} (backoff {:?})",
                 a.attempt, a.engine, a.outcome, a.backoff);
    }
    match rep.result {
        Some(res) => {
            println!("supervised run ok after {} retries (final engine '{}')",
                     rep.retries(), rep.final_engine);
            println!("  test_ppl={:.3} params_fnv={:016x} mask_rng={:016x}",
                     res.test_ppl, res.final_params_fnv, res.final_mask_rng);
            println!("  checkpoints written={} overhead={:?} resumed={}",
                     res.ckpt_written, res.ckpt_overhead, res.resumed);
            Ok(())
        }
        None => Err(err!("supervised run failed after {} attempts", rep.attempts.len())),
    }
}

/// Queue a [`JobSpec`] built from the submit flags: append it as one
/// JSON line to the jobs file (`--jobs`/`--out`), or send it to a
/// running `serve --listen` over TCP (`--connect`), retrying on `busy`
/// backpressure frames.
fn submit_cmd(flags: &Flags) -> Result<()> {
    let spec = flags.job_spec()?;
    if let Some(addr) = flags.get_str("connect") {
        return submit_over_socket(addr, spec);
    }
    let out = flags
        .get_str("jobs")
        .ok_or_else(|| err!("submit: --jobs FILE (or --connect ADDR) is required"))?;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(out)
        .map_err(|e| err!("submit: opening {out}: {e}"))?;
    writeln!(f, "{}", spec.to_json()).map_err(|e| err!("submit: writing {out}: {e}"))?;
    println!("submit: queued {} job (keep={}, variant={}) -> {out}",
             spec.task, spec.keep, spec.variant);
    Ok(())
}

/// TCP submission: `submitted` is success, `busy` means sleep for the
/// server's `retry_after_ms` hint and try again (bounded), anything else
/// is an error.
fn submit_over_socket(addr: &str, spec: JobSpec) -> Result<()> {
    let mut client = Client::connect(addr)?;
    let req = Request::Submit { spec: spec.clone() }.to_json();
    for _attempt in 0..60 {
        match Response::from_json(&client.request(&req)?)? {
            Response::Submitted { id } => {
                println!("submit: accepted by {addr} as job {id} ({} keep={})",
                         spec.task, spec.keep);
                return Ok(());
            }
            Response::Busy { retry_after_ms, depth } => {
                eprintln!("submit: {addr} busy (queue depth {depth}), \
                           retrying in {retry_after_ms}ms");
                std::thread::sleep(std::time::Duration::from_millis(retry_after_ms));
            }
            Response::Error { msg } => return Err(err!("submit: {addr}: {msg}")),
            other => return Err(err!("submit: unexpected reply {other:?}")),
        }
    }
    Err(err!("submit: {addr} stayed saturated after 60 retries"))
}

/// One-shot service counters over the socket.
fn status_cmd(flags: &Flags) -> Result<()> {
    let addr = flags
        .get_str("connect")
        .ok_or_else(|| err!("status: --connect ADDR is required"))?;
    let mut client = Client::connect(addr)?;
    match Response::from_json(&client.request(&Request::Status.to_json())?)? {
        Response::Status(s) => {
            println!("status: submitted={} done={} failed={} queue_depth={} \
                      draining={} pools={}",
                     s.submitted, s.done, s.failed, s.queue_depth, s.draining,
                     s.pools.join(","));
            Ok(())
        }
        Response::Error { msg } => Err(err!("status: {addr}: {msg}")),
        other => Err(err!("status: unexpected reply {other:?}")),
    }
}

/// Stream index records from the live service. With `--count N`, exits
/// once N terminal (`done`/`failed`) events were seen; otherwise runs
/// until the server drains and sends the final report. Exits nonzero if
/// any watched job failed.
fn watch_cmd(flags: &Flags) -> Result<()> {
    let addr = flags
        .get_str("connect")
        .ok_or_else(|| err!("watch: --connect ADDR is required"))?;
    let from: usize = flags.get("from", 0)?;
    let want: usize = flags.get("count", 0)?;
    let mut client = Client::connect(addr)?;
    client.send(&Request::Watch { from }.to_json())?;
    let (mut terminal, mut failed) = (0usize, 0usize);
    while let Some(frame) = client.recv()? {
        match Response::from_json(&frame)? {
            Response::Event { seq, record } => {
                println!("watch[{seq}] {record}");
                if let Some((_, state)) = proto::record_id_state(&record) {
                    if state == "done" || state == "failed" {
                        terminal += 1;
                        if state == "failed" {
                            failed += 1;
                        }
                    }
                }
                if want > 0 && terminal >= want {
                    break;
                }
            }
            Response::Report { report } => {
                println!("watch: service drained — {report}");
                break;
            }
            Response::Error { msg } => return Err(err!("watch: {addr}: {msg}")),
            other => return Err(err!("watch: unexpected reply {other:?}")),
        }
    }
    if want > 0 && terminal < want {
        return Err(err!("watch: stream ended after {terminal}/{want} terminal events"));
    }
    if failed > 0 {
        return Err(err!("watch: {failed} watched job(s) failed"));
    }
    println!("watch: {terminal} terminal event(s), none failed");
    Ok(())
}

/// Close the service's queue over the socket and wait for the final
/// report. Exits nonzero when the drained report counts failures.
fn drain_cmd(flags: &Flags) -> Result<()> {
    let addr = flags
        .get_str("connect")
        .ok_or_else(|| err!("drain: --connect ADDR is required"))?;
    let mut client = Client::connect(addr)?;
    match Response::from_json(&client.request(&Request::Drain.to_json())?)? {
        Response::Draining => {}
        Response::Error { msg } => return Err(err!("drain: {addr}: {msg}")),
        other => return Err(err!("drain: unexpected reply {other:?}")),
    }
    while let Some(frame) = client.recv()? {
        match Response::from_json(&frame)? {
            Response::Report { report } => {
                println!("drain: {report}");
                let failed = report.get("jobs_failed").and_then(Json::as_usize).unwrap_or(0);
                if failed > 0 {
                    return Err(err!("drain: {failed} job(s) failed"));
                }
                return Ok(());
            }
            Response::Event { .. } => {} // not subscribed, but tolerate
            Response::Error { msg } => return Err(err!("drain: {addr}: {msg}")),
            other => return Err(err!("drain: unexpected reply {other:?}")),
        }
    }
    Err(err!("drain: {addr} closed the connection before the final report"))
}

/// Run the multi-tenant experiment service. Batch mode drains the
/// `--jobs` file and exits; `--listen` additionally opens the TCP front
/// end and runs until a client drains it. Job ids are jobs-file line
/// numbers either way, so `--resume 1` can skip jobs whose index record
/// already says `done` and resume the rest from their `--ckpt-root`
/// checkpoints. Exits nonzero when any job fails.
fn serve_cmd(flags: &Flags) -> Result<()> {
    let listen = flags.get_str("listen").map(str::to_string);
    let jobs_path = flags.get_str("jobs").map(str::to_string);
    if listen.is_none() && jobs_path.is_none() {
        return Err(err!("serve: --jobs FILE (batch) or --listen ADDR is required"));
    }
    let pools = parse_pools(flags.str_or("pools", "reference:1:2"))?;
    let base = flags.run_config()?;
    let resume = base.resume.unwrap_or(false);

    let mut cfg = ServiceConfig::new(pools);
    cfg.telemetry = flags.get_str("telemetry").map(PathBuf::from);
    if cfg.telemetry.is_none() && listen.is_some() {
        // The socket front end streams `watch` events out of the live
        // index, so listen mode defaults telemetry on.
        cfg.telemetry = Some(runs_dir().join("service"));
    }
    cfg.ckpt_root = flags.get_str("ckpt-root").map(PathBuf::from);
    cfg.sup = SupervisorConfig::new(flags.get("retries", 2)?);
    cfg.base = base;

    // Preload the jobs file (it is also the socket journal). In batch
    // mode it must hold at least one job; in listen mode it may be
    // missing or empty — jobs arrive over TCP.
    let mut specs = Vec::new();
    if let Some(path) = &jobs_path {
        match std::fs::read_to_string(path) {
            Ok(text) => {
                for (lineno, line) in text.lines().enumerate() {
                    let line = line.trim();
                    if line.is_empty() {
                        continue;
                    }
                    let j = Json::parse(line)
                        .map_err(|e| err!("serve: {path} line {}: {e}", lineno + 1))?;
                    specs.push(JobSpec::from_json(&j)
                        .map_err(|e| err!("serve: {path} line {}: {e}", lineno + 1))?);
                }
            }
            Err(e) if listen.is_some() && e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => return Err(err!("serve: reading {path}: {e}")),
        }
    }
    if specs.is_empty() && listen.is_none() {
        return Err(err!("serve: {} holds no jobs", jobs_path.as_deref().unwrap_or("?")));
    }

    // On resume, the previous run's live index tells us which ids already
    // reached `done`; everything else is resubmitted with resume enabled.
    let done: HashSet<u64> = match (&cfg.telemetry, resume) {
        (Some(dir), true) => JobLogs::new(dir).done_ids().unwrap_or_default(),
        _ => HashSet::new(),
    };

    let total = specs.len();
    let svc = Service::start(cfg)?;
    let mut skipped = 0usize;
    for (i, mut spec) in specs.into_iter().enumerate() {
        let id = i as u64;
        if done.contains(&id) {
            println!("job {id}: already done, skipped");
            skipped += 1;
            continue;
        }
        if resume {
            spec.run.resume = Some(true);
        }
        svc.submit_as(id, spec)?;
    }

    let report = match listen {
        None => svc.drain()?,
        Some(addr) => {
            let server = Server::bind(ServerConfig {
                addr,
                allow_remote: flags.get("allow-remote", 0u8)? != 0,
                max_queue_depth: flags.get("max-queue", 64)?,
                retry_after_ms: flags.get("retry-after-ms", 250)?,
                journal: jobs_path.as_deref().map(PathBuf::from),
                next_id: total as u64,
            })?;
            let bound = server.local_addr()?;
            println!("serve: listening on {bound} (protocol v{})", proto::PROTO_VERSION);
            if let Some(path) = flags.get_str("addr-file") {
                std::fs::write(path, format!("{bound}\n"))
                    .map_err(|e| err!("serve: writing {path}: {e}"))?;
            }
            server.run(svc)?
        }
    };
    print_report(&report, skipped)
}

/// Per-job outcome lines plus the drained summary; errors when any job
/// failed so `serve` exits nonzero.
fn print_report(report: &ServiceReport, skipped: usize) -> Result<()> {
    let mut outs = report.outcomes.clone();
    outs.sort_by_key(|o| o.id);
    for o in &outs {
        println!("job {} [{} on {}] {}: {} attempts={} engine={} windows={} \
                  resumed={} wait={:.1}ms",
                 o.id, o.task, o.pool,
                 if o.ok { "done" } else { "failed" },
                 o.outcome, o.attempts, o.final_engine, o.windows, o.resumed,
                 o.queue_wait.as_secs_f64() * 1e3);
    }
    println!("serve: {} jobs — {} done, {} failed, {skipped} skipped; \
              {:.1} jobs/s; queue wait p50 {:.1}ms p99 {:.1}ms; steals {}; \
              cache {}/{} hits",
             outs.len() + skipped,
             report.completed(), report.failed(),
             report.throughput_jobs_per_s(),
             report.queue_wait_percentile(50.0).as_secs_f64() * 1e3,
             report.queue_wait_percentile(99.0).as_secs_f64() * 1e3,
             report.total_steals(),
             report.cache.hits, report.cache.hits + report.cache.misses);
    if report.failed() > 0 {
        return Err(err!("serve: {} job(s) failed", report.failed()));
    }
    Ok(())
}

/// Train the lowered artifact for a few steps; prints the loss curve.
fn xla_train(model: &str, steps: usize, case: DropoutCase) -> Result<()> {
    let mut reg = ArtifactRegistry::open(&ArtifactRegistry::default_dir())?;
    println!("platform: {}", reg.platform());
    let dropout = DropoutConfig { case, scope: Scope::NrRh, p_nr: 0.3, p_rh: 0.3 };
    let sgd = Sgd::new(1.0, 5.0, usize::MAX, 1.0);
    let mut trainer = XlaLmTrainer::new(&mut reg, model, dropout, sgd, 7)?;
    let m = trainer.manifest.clone();
    println!("model '{model}': V={} H={} L={} B={} T={} ({} params)",
             m.vocab, m.hidden, m.layers, m.batch, m.seq_len, m.total_params());

    let corpus = MarkovLmCorpus::new(m.vocab, 5, 0.85, 11);
    let stream = corpus.generate(m.batch * (m.seq_len * steps + 1) + m.batch, 13);
    let mut batcher = LmBatcher::new(&stream, m.batch, m.seq_len);
    for step in 0..steps {
        let win = match batcher.next_window() {
            Some(w) => w,
            None => {
                batcher.reset();
                batcher.next_window().unwrap()
            }
        };
        let loss = trainer.train_step(&win)?;
        println!("step {step:>4}  loss {loss:.4}  ppl {:.1}", loss.exp());
    }
    Ok(())
}

/// Print the four Fig. 1 cases as ASCII mask matrices.
fn mask_demo() {
    let (t, b, h) = (4, 6, 16);
    println!("Fig. 1 — dropout mask taxonomy (B={b}, H={h}, {t} time steps; #=dropped)\n");
    for case in [
        DropoutCase::RandomVarying,
        DropoutCase::RandomConstant,
        DropoutCase::StructuredVarying,
        DropoutCase::StructuredConstant,
    ] {
        println!("{}:", case.label());
        let cfg = DropoutConfig { case, scope: Scope::Nr, p_nr: 0.5, p_rh: 0.0 };
        let mut planner = MaskPlanner::new(cfg, 42);
        let plan = planner.plan(t, b, h, 1);
        for (ti, step) in plan.steps.iter().enumerate() {
            let dense = step.mx[0].to_dense(b);
            print!("  t={ti}: ");
            for r in 0..b {
                let row: String = (0..h)
                    .map(|c| if dense[r * h + c] == 0.0 { '#' } else { '.' })
                    .collect();
                print!("{row}  ");
            }
            println!();
        }
        println!();
    }
}

/// Show PJRT + artifact inventory.
fn info() -> Result<()> {
    let dir = ArtifactRegistry::default_dir();
    println!("artifacts dir: {}", dir.display());
    if !dir.join("manifest.json").exists() {
        println!("artifacts not built — run `make artifacts`");
        return Ok(());
    }
    let reg = ArtifactRegistry::open(&dir)?;
    println!("PJRT platform: {}", reg.platform());
    for (name, m) in &reg.manifest.models {
        println!("  model '{name}': V={} H={} L={} B={} T={} -> {} / {}",
                 m.vocab, m.hidden, m.layers, m.batch, m.seq_len,
                 m.step_artifact, m.eval_artifact);
    }
    if let Some(c) = &reg.manifest.cell {
        println!("  cell: B={} Dx={} H={} -> {}", c.batch, c.dx, c.hidden, c.artifact);
    }
    Ok(())
}
