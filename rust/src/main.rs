//! `sdrnn` — command-line launcher for the structured-dropout RNN stack.
//!
//! Subcommands (hand-rolled parser; clap is unavailable offline):
//!
//! ```text
//! sdrnn table1-metrics  [--hidden N] [--vocab N] [--epochs N] [--tokens N] [ckpt flags]
//! sdrnn table1-speedup  [--reps N]
//! sdrnn table2-metrics  [--hidden N] [--vocab N] [--steps N] [ckpt flags]
//! sdrnn table2-speedup  [--reps N]
//! sdrnn table3-metrics  [--hidden N] [--vocab N] [--epochs N] [ckpt flags]
//! sdrnn table3-speedup  [--reps N]
//! sdrnn supervise       [--hidden N] [--vocab N] [--epochs N] [--tokens N]
//!                       [--retries N] [--max-windows N] [ckpt flags]
//! sdrnn xla-train       [--model tiny|e2e] [--steps N] [--case I|II|III|IV]
//! sdrnn mask-demo
//! sdrnn info
//!
//! ckpt flags: [--ckpt-dir D] [--every N] [--resume 0|1] [--faults SPEC]
//!             [--timeout-ms N]
//! ```

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use sdrnn::err;
use sdrnn::util::error::Result;

use sdrnn::coordinator::experiments;
use sdrnn::coordinator::XlaLmTrainer;
use sdrnn::coordinator::{run_lm_supervised, SupervisorConfig};
use sdrnn::data::batcher::LmBatcher;
use sdrnn::data::corpus::MarkovLmCorpus;
use sdrnn::dropout::plan::{DropoutCase, DropoutConfig, MaskPlanner, Scope};
use sdrnn::optim::sgd::Sgd;
use sdrnn::runtime::ArtifactRegistry;
use sdrnn::train::checkpoint::prune;
use sdrnn::train::lm::LmTrainConfig;
use sdrnn::train::RunPolicy;
use sdrnn::util::faults::Faults;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parse `--key value` pairs after the subcommand.
fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let k = args[i]
            .strip_prefix("--")
            .ok_or_else(|| err!("expected --flag, got '{}'", args[i]))?;
        let v = args
            .get(i + 1)
            .ok_or_else(|| err!("flag --{k} needs a value"))?;
        flags.insert(k.to_string(), v.clone());
        i += 2;
    }
    Ok(flags)
}

fn get<T: std::str::FromStr>(flags: &HashMap<String, String>, k: &str, default: T) -> Result<T> {
    match flags.get(k) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| err!("bad value for --{k}: '{v}'")),
    }
}

/// Build a [`RunPolicy`] from the shared ckpt flags: `--ckpt-dir`,
/// `--every`, `--faults`, `--timeout-ms`. `--resume 0` (the default)
/// clears any stale snapshots so the run truly starts fresh.
fn policy_from_flags(flags: &HashMap<String, String>) -> Result<(RunPolicy, bool)> {
    let mut policy = match flags.get("ckpt-dir") {
        Some(d) => RunPolicy::every(Path::new(d), get(flags, "every", 25)?),
        None => RunPolicy::none(),
    };
    if let Some(spec) = flags.get("faults") {
        policy.faults = Some(Arc::new(Faults::parse(spec)?));
    }
    let timeout_ms = get(flags, "timeout-ms", 0u64)?;
    if timeout_ms > 0 {
        policy.window_timeout = Some(Duration::from_millis(timeout_ms));
    }
    let resume = get(flags, "resume", 0usize)? != 0;
    if !resume {
        if let Some(dir) = &policy.ckpt_dir {
            prune(dir, 0);
        }
    }
    Ok((policy, resume))
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(args.get(1..).unwrap_or(&[]))?;

    match cmd {
        "table1-metrics" => {
            let (policy, resume) = policy_from_flags(&flags)?;
            let rows = experiments::table1_metric_rows_ckpt(
                get(&flags, "hidden", 64)?,
                get(&flags, "vocab", 2000)?,
                get(&flags, "epochs", 4)?,
                get(&flags, "tokens", 120_000)?,
                get(&flags, "seed", 1u64)?,
                &policy,
                resume,
            )?;
            println!("Table 1 (metrics, scaled synthetic PTB):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "table1-speedup" => {
            let rows = experiments::table1_speedup_rows(get(&flags, "reps", 3)?,
                                                        get(&flags, "seed", 1u64)?);
            println!("Table 1 (speedups at paper shapes):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "table2-metrics" => {
            let (policy, resume) = policy_from_flags(&flags)?;
            let rows = experiments::table2_metric_rows_ckpt(
                get(&flags, "hidden", 32)?,
                get(&flags, "vocab", 200)?,
                get(&flags, "steps", 300)?,
                get(&flags, "seed", 1u64)?,
                &policy,
                resume,
            )?;
            println!("Table 2 (metrics, synthetic transduction corpus):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "table2-speedup" => {
            let rows = experiments::table2_speedup_rows(get(&flags, "reps", 3)?,
                                                        get(&flags, "seed", 1u64)?);
            println!("Table 2 (speedups at paper shapes):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "table3-metrics" => {
            let (policy, resume) = policy_from_flags(&flags)?;
            let rows = experiments::table3_metric_rows_ckpt(
                get(&flags, "hidden", 24)?,
                get(&flags, "vocab", 600)?,
                get(&flags, "epochs", 3)?,
                get(&flags, "seed", 1u64)?,
                &policy,
                resume,
            )?;
            println!("Table 3 (metrics, synthetic CoNLL):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "table3-speedup" => {
            let rows = experiments::table3_speedup_rows(get(&flags, "reps", 3)?,
                                                        get(&flags, "seed", 1u64)?);
            println!("Table 3 (speedups at paper shapes):");
            for r in rows {
                println!("  {}", r.format());
            }
        }
        "xla-train" => {
            let model = flags.get("model").cloned().unwrap_or_else(|| "tiny".into());
            let steps = get(&flags, "steps", 20)?;
            let case = match flags.get("case").map(String::as_str).unwrap_or("III") {
                "I" => DropoutCase::RandomVarying,
                "II" => DropoutCase::RandomConstant,
                "III" => DropoutCase::StructuredVarying,
                "IV" => DropoutCase::StructuredConstant,
                c => return Err(err!("unknown case '{c}' (use I..IV)")),
            };
            xla_train(&model, steps, case)?;
        }
        "supervise" => supervise_cmd(&flags)?,
        "mask-demo" => mask_demo(),
        "info" => info()?,
        _ => {
            println!("{}", HELP);
        }
    }
    Ok(())
}

const HELP: &str = "\
sdrnn — Structured in Space, Randomized in Time (NeurIPS 2021) reproduction

USAGE: sdrnn <subcommand> [--flag value]...

  table1-metrics / table1-speedup    PTB language modelling (Table 1)
  table2-metrics / table2-speedup    IWSLT machine translation (Table 2)
  table3-metrics / table3-speedup    CoNLL-2003 NER (Table 3)
  supervise   fault-tolerant LM run: checkpoints, retries, resume
  xla-train   train the AOT-lowered XLA LM artifact from Rust
  mask-demo   print the Fig. 1 mask taxonomy
  info        PJRT platform + artifact inventory

Fault-tolerance flags (metric tables + supervise):
  --ckpt-dir D     snapshot directory (enables checkpointing)
  --every N        snapshot every N windows (default 25)
  --resume 0|1     1 = continue from the newest loadable snapshot;
                   0 = fresh run (stale snapshots are cleared)
  --faults SPEC    deterministic fault schedule (SDRNN_FAULTS grammar)
  --timeout-ms N   per-window watchdog limit

Benches regenerate the full tables: `cargo bench --bench table1_ptb` etc.
Examples: `cargo run --release --example e2e_lm_ptb` (end-to-end driver).";

/// Supervised LM run on the synthetic PTB: periodic checkpoints, panic
/// capture, retry with backoff, engine degradation, and resume from the
/// newest loadable snapshot. Exits nonzero when every attempt fails —
/// the CI crash-recovery smoke drives this subcommand with an injected
/// kill and then resumes it.
fn supervise_cmd(flags: &HashMap<String, String>) -> Result<()> {
    let task = flags.get("task").map(String::as_str).unwrap_or("lm");
    if task != "lm" {
        return Err(err!("supervise: unknown task '{task}' (only 'lm' is wired up)"));
    }
    let hidden = get(flags, "hidden", 16)?;
    let vocab = get(flags, "vocab", 60)?;
    let seed = get(flags, "seed", 1u64)?;
    let (policy, resume) = policy_from_flags(flags)?;

    let corpus = MarkovLmCorpus::new(vocab, 5, 0.85, seed);
    let (tr, va, te) = corpus.splits(get(flags, "tokens", 40_000)?);
    let mut cfg = LmTrainConfig::zaremba_medium(hidden, vocab, DropoutConfig::nr_st(0.5));
    cfg.epochs = get(flags, "epochs", 2)?;
    cfg.seed = seed;
    let cap = get(flags, "max-windows", 0usize)?;
    if cap > 0 {
        cfg.max_windows_per_epoch = Some(cap);
    }

    let sup = SupervisorConfig::new(get(flags, "retries", 3)?);
    let ckpt_desc = match &policy.ckpt_dir {
        Some(d) => d.display().to_string(),
        None => "(off)".to_string(),
    };
    println!("supervise: task=lm hidden={hidden} vocab={vocab} epochs={} resume={resume} \
              ckpt={ckpt_desc}",
             cfg.epochs);
    let rep = run_lm_supervised(&cfg, &tr, &va, &te, &policy, &sup);
    for a in &rep.attempts {
        println!("  attempt {} [{}]: {} (backoff {:?})",
                 a.attempt, a.engine, a.outcome, a.backoff);
    }
    match rep.result {
        Some(res) => {
            println!("supervised run ok after {} retries (final engine '{}')",
                     rep.retries(), rep.final_engine);
            println!("  test_ppl={:.3} params_fnv={:016x} mask_rng={:016x}",
                     res.test_ppl, res.final_params_fnv, res.final_mask_rng);
            println!("  checkpoints written={} overhead={:?} resumed={}",
                     res.ckpt_written, res.ckpt_overhead, res.resumed);
            Ok(())
        }
        None => Err(err!("supervised run failed after {} attempts", rep.attempts.len())),
    }
}

/// Train the lowered artifact for a few steps; prints the loss curve.
fn xla_train(model: &str, steps: usize, case: DropoutCase) -> Result<()> {
    let mut reg = ArtifactRegistry::open(&ArtifactRegistry::default_dir())?;
    println!("platform: {}", reg.platform());
    let dropout = DropoutConfig { case, scope: Scope::NrRh, p_nr: 0.3, p_rh: 0.3 };
    let sgd = Sgd::new(1.0, 5.0, usize::MAX, 1.0);
    let mut trainer = XlaLmTrainer::new(&mut reg, model, dropout, sgd, 7)?;
    let m = trainer.manifest.clone();
    println!("model '{model}': V={} H={} L={} B={} T={} ({} params)",
             m.vocab, m.hidden, m.layers, m.batch, m.seq_len, m.total_params());

    let corpus = MarkovLmCorpus::new(m.vocab, 5, 0.85, 11);
    let stream = corpus.generate(m.batch * (m.seq_len * steps + 1) + m.batch, 13);
    let mut batcher = LmBatcher::new(&stream, m.batch, m.seq_len);
    for step in 0..steps {
        let win = match batcher.next_window() {
            Some(w) => w,
            None => {
                batcher.reset();
                batcher.next_window().unwrap()
            }
        };
        let loss = trainer.train_step(&win)?;
        println!("step {step:>4}  loss {loss:.4}  ppl {:.1}", loss.exp());
    }
    Ok(())
}

/// Print the four Fig. 1 cases as ASCII mask matrices.
fn mask_demo() {
    let (t, b, h) = (4, 6, 16);
    println!("Fig. 1 — dropout mask taxonomy (B={b}, H={h}, {t} time steps; #=dropped)\n");
    for case in [
        DropoutCase::RandomVarying,
        DropoutCase::RandomConstant,
        DropoutCase::StructuredVarying,
        DropoutCase::StructuredConstant,
    ] {
        println!("{}:", case.label());
        let cfg = DropoutConfig { case, scope: Scope::Nr, p_nr: 0.5, p_rh: 0.0 };
        let mut planner = MaskPlanner::new(cfg, 42);
        let plan = planner.plan(t, b, h, 1);
        for (ti, step) in plan.steps.iter().enumerate() {
            let dense = step.mx[0].to_dense(b);
            print!("  t={ti}: ");
            for r in 0..b {
                let row: String = (0..h)
                    .map(|c| if dense[r * h + c] == 0.0 { '#' } else { '.' })
                    .collect();
                print!("{row}  ");
            }
            println!();
        }
        println!();
    }
}

/// Show PJRT + artifact inventory.
fn info() -> Result<()> {
    let dir = ArtifactRegistry::default_dir();
    println!("artifacts dir: {}", dir.display());
    if !dir.join("manifest.json").exists() {
        println!("artifacts not built — run `make artifacts`");
        return Ok(());
    }
    let reg = ArtifactRegistry::open(&dir)?;
    println!("PJRT platform: {}", reg.platform());
    for (name, m) in &reg.manifest.models {
        println!("  model '{name}': V={} H={} L={} B={} T={} -> {} / {}",
                 m.vocab, m.hidden, m.layers, m.batch, m.seq_len,
                 m.step_artifact, m.eval_artifact);
    }
    if let Some(c) = &reg.manifest.cell {
        println!("  cell: B={} Dx={} H={} -> {}", c.batch, c.dx, c.hidden, c.artifact);
    }
    Ok(())
}
